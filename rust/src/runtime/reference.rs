//! Pure-Rust reference backend: the default, dependency-free executor.
//!
//! Ports the linear+softmax reference model and the kernel oracles of
//! `python/compile/kernels/ref.py` to Rust so the entire sampler →
//! batcher → trainer → accountant → report pipeline runs end-to-end
//! offline, with the exact Algorithm 1/2 semantics:
//!
//! * per-example gradients of softmax cross-entropy over one linear
//!   layer (`logits = W x + b`, flat params `[W row-major | b]`),
//! * per-example squared grad norms via the closed form
//!   `||g_i||^2 = ||dlogits_i||^2 * (||x_i||^2 + 1)` (weight ⊗ input
//!   outer product plus the bias row — for a single linear layer this
//!   equals the ghost-norm trick, which is why the `ghost`/`bk`
//!   variants share the per-example path here),
//! * masked clip-and-accumulate `acc += mask_i * min(1, C/||g_i||) g_i`,
//! * the noisy step `params - lr * (acc + sigma*C*z) / denom` with
//!   ChaCha20-seeded Gaussian noise from the 64-bit per-step seed.
//!
//! "Compilation" is a spec decode, timed through the same
//! [`CompileCache`] as PJRT so the masked-vs-naive compile-count
//! invariants (Fig. A.2) are observable on this backend too.

// The ABI methods carry the full flat-param call (8-9 args by design).
#![allow(clippy::too_many_arguments)]

use super::backend::{AccumOut, Backend, Prepared};
use super::compile_cache::{CompileCache, CompileRecord};
use super::manifest::{ExecutableMeta, Manifest, ModelMeta};
use super::tensor::Tensor;
use crate::util::rng::ChaChaRng;
use anyhow::{anyhow, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

/// Name of the synthetic reference model in [`ReferenceBackend::manifest`].
pub const REFERENCE_MODEL: &str = "ref-linear";

/// Decoded executable spec (the reference backend's "compiled" form).
#[derive(Debug, Clone)]
enum RefExec {
    Accum { variant: String, batch: usize },
    Apply,
    Eval { batch: usize },
}

/// The pure-Rust reference CPU backend.
pub struct ReferenceBackend {
    cache: RefCell<CompileCache<RefExec>>,
    /// Seed for the synthesized initial parameters.
    init_seed: u64,
}

impl ReferenceBackend {
    pub fn new(init_seed: u64) -> Self {
        Self { cache: RefCell::new(CompileCache::new()), init_seed }
    }

    /// In-memory manifest for the reference model: every clipping
    /// variant at a ladder of physical batch sizes, plus apply/eval —
    /// the same catalog shape `python/compile/aot.py` writes for real
    /// artifacts, so the trainer cannot tell the backends apart.
    pub fn manifest(seed: u64) -> Manifest {
        let image = 16;
        let channels = 3;
        let num_classes = 10;
        let d = image * image * channels;
        let mut executables = Vec::new();
        for variant in ["nonprivate", "naive", "masked", "ghost", "bk"] {
            for batch in [1usize, 2, 4, 8, 16, 32, 64] {
                executables.push(ExecutableMeta {
                    path: format!("{REFERENCE_MODEL}_accum_{variant}_b{batch}_f32.ref"),
                    kind: "accum".into(),
                    variant: Some(variant.into()),
                    batch: Some(batch),
                    dtype: Some("f32".into()),
                });
            }
        }
        executables.push(ExecutableMeta {
            path: format!("{REFERENCE_MODEL}_apply.ref"),
            kind: "apply".into(),
            variant: None,
            batch: None,
            dtype: None,
        });
        executables.push(ExecutableMeta {
            path: format!("{REFERENCE_MODEL}_eval_b32.ref"),
            kind: "eval".into(),
            variant: None,
            batch: Some(32),
            dtype: None,
        });
        let meta = ModelMeta {
            family: "linear".into(),
            n_params: num_classes * d + num_classes,
            image,
            channels,
            num_classes,
            clip_norm: 1.0,
            flops_fwd_per_example: (2 * num_classes * d) as f64,
            init_params: format!("{REFERENCE_MODEL}_init.synthetic"),
            executables,
        };
        let mut models = BTreeMap::new();
        models.insert(REFERENCE_MODEL.to_string(), meta);
        Manifest { version: 1, seed, models }
    }

    fn spec(&self, prep: &Prepared) -> Result<Arc<RefExec>> {
        self.cache
            .borrow()
            .get_cached(&prep.key)
            .ok_or_else(|| anyhow!("executable {} was not prepared", prep.key))
    }

    fn check_model_vectors(meta: &ModelMeta, params: &Tensor, acc: Option<&Tensor>) -> Result<()> {
        if params.len() != meta.n_params {
            return Err(anyhow!(
                "params length {} != n_params {}",
                params.len(),
                meta.n_params
            ));
        }
        if let Some(acc) = acc {
            if acc.len() != meta.n_params {
                return Err(anyhow!(
                    "acc length {} != n_params {}",
                    acc.len(),
                    meta.n_params
                ));
            }
        }
        Ok(())
    }

    fn check_batch(meta: &ModelMeta, x: &[f32], y: &[i32]) -> Result<()> {
        let d = image_dim(meta);
        if x.len() != y.len() * d {
            return Err(anyhow!(
                "x length {} != batch {} * image dim {}",
                x.len(),
                y.len(),
                d
            ));
        }
        for &yi in y {
            if yi < 0 || yi as usize >= meta.num_classes {
                return Err(anyhow!(
                    "label {yi} out of range for {} classes",
                    meta.num_classes
                ));
            }
        }
        Ok(())
    }
}

fn image_dim(meta: &ModelMeta) -> usize {
    meta.image * meta.image * meta.channels
}

/// `logits = W x + b` over the flat parameter layout `[W row-major | b]`.
fn logits(meta: &ModelMeta, params: &[f32], xi: &[f32]) -> Vec<f32> {
    let d = image_dim(meta);
    let ncls = meta.num_classes;
    let (w, rest) = params.split_at(ncls * d);
    let bias = &rest[..ncls];
    let mut out = Vec::with_capacity(ncls);
    for (cls, &b) in bias.iter().enumerate() {
        let row = &w[cls * d..(cls + 1) * d];
        let dot: f32 = row.iter().zip(xi).map(|(wv, xv)| wv * xv).sum();
        out.push(dot + b);
    }
    out
}

/// Stable log-sum-exp of the logits.
fn logsumexp(lg: &[f32]) -> f32 {
    let max = lg.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let z: f32 = lg.iter().map(|&l| (l - max).exp()).sum();
    max + z.ln()
}

/// Cross-entropy loss and `dlogits = softmax(logits) - onehot(y)`.
fn loss_and_dlogits(lg: &[f32], y: usize) -> (f32, Vec<f32>) {
    let max = lg.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<f32> = lg.iter().map(|&l| (l - max).exp()).collect();
    let z: f32 = probs.iter().sum();
    let loss = max + z.ln() - lg[y];
    for p in probs.iter_mut() {
        *p /= z;
    }
    probs[y] -= 1.0;
    (loss, probs)
}

/// `acc += scale * g_i` for the linear model's per-example gradient
/// `g_i = (dlogits ⊗ x_i, dlogits)` — no `[B, P]` materialization.
fn accumulate_scaled_grad(acc: &mut [f32], ncls: usize, d: usize, scale: f32, dlog: &[f32], xi: &[f32]) {
    for (cls, &dl) in dlog.iter().enumerate() {
        let g = scale * dl;
        let row = &mut acc[cls * d..(cls + 1) * d];
        for (a, &xv) in row.iter_mut().zip(xi) {
            *a += g * xv;
        }
        acc[ncls * d + cls] += g;
    }
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn prepare(&self, _dir: &Path, _meta: &ModelMeta, exe: &ExecutableMeta) -> Result<Prepared> {
        let spec = match exe.kind.as_str() {
            "accum" => RefExec::Accum {
                variant: exe
                    .variant
                    .clone()
                    .ok_or_else(|| anyhow!("accum artifact {} missing variant", exe.path))?,
                batch: exe
                    .batch
                    .ok_or_else(|| anyhow!("accum artifact {} missing batch", exe.path))?,
            },
            "apply" => RefExec::Apply,
            "eval" => RefExec::Eval {
                batch: exe
                    .batch
                    .ok_or_else(|| anyhow!("eval artifact {} missing batch", exe.path))?,
            },
            other => return Err(anyhow!("unknown executable kind {other:?} for {}", exe.path)),
        };
        let (_, compile_seconds) =
            self.cache.borrow_mut().get_or_compile(&exe.path, || Ok(spec))?;
        Ok(Prepared { key: exe.path.clone(), compile_seconds })
    }

    fn is_compiled(&self, key: &str) -> bool {
        self.cache.borrow().is_cached(key)
    }

    fn compile_records(&self) -> Vec<CompileRecord> {
        self.cache.borrow().records().to_vec()
    }

    /// Synthesized deterministic init: small Gaussian weights, zero
    /// biases (no artifact file to read).
    fn init_params(&self, _dir: &Path, meta: &ModelMeta) -> Result<Tensor> {
        let d = image_dim(meta);
        let ncls = meta.num_classes;
        let mut rng = ChaChaRng::from_seed_stream(self.init_seed, 0, b"refinit\0");
        let mut v = Vec::with_capacity(meta.n_params);
        for _ in 0..ncls * d {
            v.push((0.05 * rng.next_normal()) as f32);
        }
        v.resize(meta.n_params, 0.0);
        Ok(Tensor::from_vec(v))
    }

    fn run_accum(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<AccumOut> {
        let spec = self.spec(prep)?;
        let (variant, batch) = match spec.as_ref() {
            RefExec::Accum { variant, batch } => (variant.as_str(), *batch),
            _ => return Err(anyhow!("{} is not an accum executable", prep.key)),
        };
        let b = y.len();
        if b != batch {
            return Err(anyhow!("accum batch mismatch: executable {batch}, got {b}"));
        }
        if mask.len() != b {
            return Err(anyhow!("mask length {} != batch {b}", mask.len()));
        }
        Self::check_model_vectors(meta, params, Some(acc))?;
        Self::check_batch(meta, x, y)?;

        let d = image_dim(meta);
        let ncls = meta.num_classes;
        let p = params.as_slice();
        let mut acc_out = acc.to_vec();
        let mut loss_sum = 0.0f32;
        let mut sq_norms = Vec::with_capacity(b);
        for i in 0..b {
            let xi = &x[i * d..(i + 1) * d];
            let m = mask[i];
            let lg = logits(meta, p, xi);
            let (loss, dlog) = loss_and_dlogits(&lg, y[i] as usize);
            loss_sum += m * loss;
            if variant == "nonprivate" {
                // Batched-gradient baseline: no clipping, norms reported
                // as zeros (matching `_accum_nonprivate` in model.py).
                sq_norms.push(0.0);
                if m != 0.0 {
                    accumulate_scaled_grad(&mut acc_out, ncls, d, m, &dlog, xi);
                }
            } else {
                let xsq: f32 = xi.iter().map(|v| v * v).sum();
                let dlsq: f32 = dlog.iter().map(|v| v * v).sum();
                let sq = dlsq * (xsq + 1.0);
                sq_norms.push(sq);
                let norm = sq.max(0.0).sqrt().max(1e-12);
                let cfac = ((meta.clip_norm as f32) / norm).min(1.0) * m;
                if cfac != 0.0 {
                    accumulate_scaled_grad(&mut acc_out, ncls, d, cfac, &dlog, xi);
                }
            }
        }
        Ok(AccumOut { acc: Tensor::from_vec(acc_out), loss_sum, sq_norms })
    }

    fn run_apply(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        acc: &Tensor,
        seed: u64,
        denom: f32,
        lr: f32,
        noise_mult: f32,
    ) -> Result<Tensor> {
        let spec = self.spec(prep)?;
        if !matches!(spec.as_ref(), RefExec::Apply) {
            return Err(anyhow!("{} is not an apply executable", prep.key));
        }
        Self::check_model_vectors(meta, params, Some(acc))?;
        if !denom.is_finite() || denom <= 0.0 {
            return Err(anyhow!("apply denom must be positive, got {denom}"));
        }
        let mut out = params.to_vec();
        if noise_mult != 0.0 {
            let mut rng = ChaChaRng::from_seed_stream(seed, 0, b"applynse");
            for (pj, &aj) in out.iter_mut().zip(acc.as_slice()) {
                let z = rng.next_normal() as f32;
                *pj -= lr * (aj + noise_mult * z) / denom;
            }
        } else {
            for (pj, &aj) in out.iter_mut().zip(acc.as_slice()) {
                *pj -= lr * aj / denom;
            }
        }
        Ok(Tensor::from_vec(out))
    }

    fn run_eval(
        &self,
        prep: &Prepared,
        meta: &ModelMeta,
        params: &Tensor,
        x: &[f32],
        y: &[i32],
    ) -> Result<(f32, f32)> {
        let spec = self.spec(prep)?;
        let batch = match spec.as_ref() {
            RefExec::Eval { batch } => *batch,
            _ => return Err(anyhow!("{} is not an eval executable", prep.key)),
        };
        if y.len() != batch {
            return Err(anyhow!("eval batch must be exactly {batch}, got {}", y.len()));
        }
        Self::check_model_vectors(meta, params, None)?;
        Self::check_batch(meta, x, y)?;
        let d = image_dim(meta);
        let p = params.as_slice();
        let mut loss_sum = 0.0f32;
        let mut ncorrect = 0.0f32;
        for (i, &yi) in y.iter().enumerate() {
            let xi = &x[i * d..(i + 1) * d];
            let lg = logits(meta, p, xi);
            loss_sum += logsumexp(&lg) - lg[yi as usize];
            let mut best = 0usize;
            for (j, &v) in lg.iter().enumerate() {
                if v > lg[best] {
                    best = j;
                }
            }
            if best == yi as usize {
                ncorrect += 1.0;
            }
        }
        Ok((loss_sum, ncorrect))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (ReferenceBackend, ModelMeta) {
        let backend = ReferenceBackend::new(0);
        let manifest = ReferenceBackend::manifest(0);
        let meta = manifest.models[REFERENCE_MODEL].clone();
        (backend, meta)
    }

    fn prepare_accum(b: &ReferenceBackend, meta: &ModelMeta, variant: &str, batch: usize) -> Prepared {
        let exe = meta.find_accum(variant, batch, "f32").expect("lowered").clone();
        b.prepare(Path::new("."), meta, &exe).unwrap()
    }

    fn batch_of(meta: &ModelMeta, n: usize) -> (Vec<f32>, Vec<i32>) {
        let d = image_dim(meta);
        let mut rng = ChaChaRng::from_seed_stream(7, 1, b"testdata");
        let x: Vec<f32> = (0..n * d).map(|_| rng.next_normal() as f32).collect();
        let y: Vec<i32> = (0..n).map(|i| (i % meta.num_classes) as i32).collect();
        (x, y)
    }

    #[test]
    fn manifest_is_complete() {
        let m = ReferenceBackend::manifest(0);
        let meta = m.model(REFERENCE_MODEL).unwrap();
        assert!(meta.find_apply().is_some());
        assert_eq!(meta.find_eval().and_then(|e| e.batch), Some(32));
        assert_eq!(meta.accum_batches("masked", "f32"), vec![1, 2, 4, 8, 16, 32, 64]);
        assert_eq!(meta.n_params, 10 * 16 * 16 * 3 + 10);
        assert!(meta.variants().contains(&"nonprivate".to_string()));
    }

    #[test]
    fn init_params_deterministic_and_nondegenerate() {
        let (b, meta) = setup();
        let p1 = b.init_params(Path::new("."), &meta).unwrap();
        let p2 = b.init_params(Path::new("."), &meta).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(p1.len(), meta.n_params);
        let nonzero = p1.as_slice().iter().filter(|v| **v != 0.0).count();
        assert!(nonzero > meta.n_params / 2);
        let other = ReferenceBackend::new(1).init_params(Path::new("."), &meta).unwrap();
        assert_ne!(p1, other);
    }

    #[test]
    fn masked_examples_contribute_nothing() {
        let (b, meta) = setup();
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let acc = Tensor::zeros(meta.n_params);
        let d = image_dim(&meta);
        let (x, y) = batch_of(&meta, 4);
        // Batch of 4 with the last two slots masked out (Alg. 2 padding)
        // must equal the same two live examples run at batch 2.
        let prep4 = prepare_accum(&b, &meta, "masked", 4);
        let padded = b
            .run_accum(&prep4, &meta, &params, &acc, &x, &y, &[1.0, 1.0, 0.0, 0.0])
            .unwrap();
        let prep2 = prepare_accum(&b, &meta, "masked", 2);
        let live = b
            .run_accum(&prep2, &meta, &params, &acc, &x[..2 * d], &y[..2], &[1.0, 1.0])
            .unwrap();
        assert_eq!(padded.acc, live.acc);
        assert_eq!(padded.loss_sum, live.loss_sum);
        // All-masked batch: accumulator unchanged, loss zero.
        let none = b
            .run_accum(&prep4, &meta, &params, &acc, &x, &y, &[0.0; 4])
            .unwrap();
        assert_eq!(none.acc, acc);
        assert_eq!(none.loss_sum, 0.0);
        // Norms are still reported for every slot (B of them).
        assert_eq!(none.sq_norms.len(), 4);
    }

    #[test]
    fn clipped_accumulator_norm_bounded_by_batch_times_clip() {
        let (b, meta) = setup();
        let prep = prepare_accum(&b, &meta, "masked", 8);
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let acc = Tensor::zeros(meta.n_params);
        let (x, y) = batch_of(&meta, 8);
        let out = b
            .run_accum(&prep, &meta, &params, &acc, &x, &y, &[1.0; 8])
            .unwrap();
        let norm: f32 = out
            .acc
            .as_slice()
            .iter()
            .map(|v| v * v)
            .sum::<f32>()
            .sqrt();
        // Triangle inequality: ||sum of clipped grads|| <= B * C.
        assert!(norm <= 8.0 * meta.clip_norm as f32 + 1e-4, "norm {norm}");
        assert!(out.loss_sum > 0.0);
        assert!(out.sq_norms.iter().all(|s| *s >= 0.0 && s.is_finite()));
    }

    #[test]
    fn nonprivate_reports_zero_norms_and_skips_clipping() {
        let (b, meta) = setup();
        let prep = prepare_accum(&b, &meta, "nonprivate", 2);
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let acc = Tensor::zeros(meta.n_params);
        let (x, y) = batch_of(&meta, 2);
        let out = b
            .run_accum(&prep, &meta, &params, &acc, &x, &y, &[1.0, 1.0])
            .unwrap();
        assert_eq!(out.sq_norms, vec![0.0, 0.0]);
        let norm: f32 = out.acc.as_slice().iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(norm > 0.0);
    }

    #[test]
    fn ghost_variant_matches_per_example_path() {
        // Single linear layer: the ghost-norm trick is exact, so ghost
        // and masked produce identical accumulators.
        let (b, meta) = setup();
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let acc = Tensor::zeros(meta.n_params);
        let (x, y) = batch_of(&meta, 4);
        let masked = prepare_accum(&b, &meta, "masked", 4);
        let ghost = prepare_accum(&b, &meta, "ghost", 4);
        let a = b.run_accum(&masked, &meta, &params, &acc, &x, &y, &[1.0; 4]).unwrap();
        let g = b.run_accum(&ghost, &meta, &params, &acc, &x, &y, &[1.0; 4]).unwrap();
        assert_eq!(a.acc, g.acc);
        assert_eq!(a.sq_norms, g.sq_norms);
    }

    #[test]
    fn apply_without_noise_is_plain_sgd_and_with_noise_is_seeded() {
        let (b, meta) = setup();
        let apply_meta = meta.find_apply().unwrap().clone();
        let prep = b.prepare(Path::new("."), &meta, &apply_meta).unwrap();
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let mut acc = Tensor::zeros(meta.n_params);
        acc.as_mut_slice()[0] = 2.0;
        let out = b
            .run_apply(&prep, &meta, &params, &acc, 42, 4.0, 0.1, 0.0)
            .unwrap();
        let want = params.as_slice()[0] - 0.1 * 2.0 / 4.0;
        assert!((out.as_slice()[0] - want).abs() < 1e-7);
        assert_eq!(out.as_slice()[1], params.as_slice()[1]);
        // Noise: deterministic per seed, different across seeds.
        let n1 = b.run_apply(&prep, &meta, &params, &acc, 7, 4.0, 0.1, 1.0).unwrap();
        let n2 = b.run_apply(&prep, &meta, &params, &acc, 7, 4.0, 0.1, 1.0).unwrap();
        let n3 = b.run_apply(&prep, &meta, &params, &acc, 8, 4.0, 0.1, 1.0).unwrap();
        assert_eq!(n1, n2);
        assert_ne!(n1, n3);
        assert_ne!(n1, out);
    }

    #[test]
    fn eval_counts_and_losses_are_sane() {
        let (b, meta) = setup();
        let eval_meta = meta.find_eval().unwrap().clone();
        let prep = b.prepare(Path::new("."), &meta, &eval_meta).unwrap();
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let (x, y) = batch_of(&meta, 32);
        let (loss, ncorrect) = b.run_eval(&prep, &meta, &params, &x, &y).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0.0..=32.0).contains(&ncorrect));
        // Wrong batch size is a clean error.
        let (x2, y2) = batch_of(&meta, 8);
        assert!(b.run_eval(&prep, &meta, &params, &x2, &y2).is_err());
    }

    #[test]
    fn prepare_caches_and_reports_compiles_once() {
        let (b, meta) = setup();
        let exe = meta.find_accum("masked", 8, "f32").unwrap().clone();
        let p1 = b.prepare(Path::new("."), &meta, &exe).unwrap();
        assert!(p1.compile_seconds.is_some());
        assert!(b.is_compiled(&p1.key));
        let p2 = b.prepare(Path::new("."), &meta, &exe).unwrap();
        assert!(p2.compile_seconds.is_none(), "second prepare must be a cache hit");
        assert_eq!(b.compile_records().len(), 1);
    }

    #[test]
    fn out_of_range_label_is_an_error() {
        let (b, meta) = setup();
        let prep = prepare_accum(&b, &meta, "masked", 1);
        let params = b.init_params(Path::new("."), &meta).unwrap();
        let acc = Tensor::zeros(meta.n_params);
        let d = image_dim(&meta);
        let x = vec![0.0f32; d];
        assert!(b.run_accum(&prep, &meta, &params, &acc, &x, &[99], &[1.0]).is_err());
        assert!(b.run_accum(&prep, &meta, &params, &acc, &x, &[-1], &[1.0]).is_err());
    }
}
