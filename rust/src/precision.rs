//! Lower-precision (TF32) study substrate (paper Section 5.2, Fig. 5/A.3).
//!
//! TF32 runs matmuls on tensor cores with fp32 range and 10-bit mantissa,
//! speeding up compute-bound (matmul) work while leaving memory-bound
//! work untouched. On this CPU testbed we exercise the numerical code
//! path with bf16 AOT variants of the same graphs (measured), and model
//! the *paper-scale* throughput ratio with a two-phase roofline:
//!
//!   t_fp32 = t_mm + t_other
//!   t_tf32 = t_mm / s + t_other          (s = tensor-core speedup)
//!   ratio  = t_fp32 / t_tf32
//!
//! The paper's Figure 5 shape falls out of how `t_other` differs by
//! method: non-private models get more matmul-bound with size, so the
//! ratio grows monotonically; private per-example training adds an
//! O(B*P) bandwidth-bound term that grows *faster* than the matmul share
//! after ViT-Base (and forces smaller physical batches, hurting
//! utilization), so its ratio peaks near Base and declines for
//! Large/Huge — exactly what we assert in tests.

use crate::clipping::ClippingMethod;
use crate::models::Arch;

/// TF32 roofline parameters (A100: TF32 tensor-core peak is ~8x the
/// fp32 FMA peak; effective end-to-end speedup on matmul-heavy layers is
/// well below peak).
#[derive(Debug, Clone, Copy)]
pub struct Tf32Model {
    /// Effective matmul speedup under TF32.
    pub matmul_speedup: f64,
    /// Non-matmul fraction of non-private step time for a *small* model.
    pub other_frac_small: f64,
    /// How fast the non-matmul fraction shrinks with model dim (bigger
    /// matrices amortize elementwise/memory work).
    pub other_shrink: f64,
    /// Per-example-gradient bandwidth term coefficient (private only):
    /// seconds-equivalent fraction proportional to P (bytes moved for
    /// [B, P] grads never speeds up under TF32).
    pub perexample_coeff: f64,
}

impl Default for Tf32Model {
    fn default() -> Self {
        Self {
            matmul_speedup: 4.0,
            other_frac_small: 0.55,
            other_shrink: 0.35,
            perexample_coeff: 6.0e-9,
        }
    }
}

impl Tf32Model {
    /// Matmul fraction of the non-private step for `arch` (grows with
    /// model size towards 1).
    fn matmul_frac(&self, arch: &Arch) -> f64 {
        // Characteristic size: params in millions, saturating.
        let pm = arch.params_m();
        let other = self.other_frac_small / (1.0 + self.other_shrink * pm.sqrt());
        1.0 - other
    }

    /// Predicted TF32/FP32 throughput ratio (higher = TF32 helps more).
    pub fn throughput_ratio(&self, arch: &Arch, method: ClippingMethod) -> f64 {
        let mm = self.matmul_frac(arch);
        let other = 1.0 - mm;
        match method {
            ClippingMethod::NonPrivate => {
                let t_tf32 = mm / self.matmul_speedup + other;
                1.0 / t_tf32
            }
            _ => {
                // Private: add the bandwidth-bound per-example-gradient
                // term (proportional to P, unaffected by TF32).
                let pe = self.perexample_coeff * arch.params() as f64;
                let t_fp32 = 1.0 + pe;
                let t_tf32 = mm / self.matmul_speedup + other + pe;
                t_fp32 / t_tf32
            }
        }
    }
}

/// One measured bf16-vs-f32 throughput comparison (schema-v5 tagged
/// accum rows at the same `(model, variant, batch, kernel)` point).
#[derive(Debug, Clone, PartialEq)]
pub struct DtypeRatio {
    pub model: String,
    pub variant: String,
    pub batch: usize,
    /// Kernel axis both rows were measured on ("scalar" | "simd").
    pub kernel: String,
    /// bf16 median over f32 median (> 1 = bf16 storage ran faster).
    pub ratio: f64,
}

/// Measured counterpart of [`Tf32Model::throughput_ratio`]: pair every
/// f32-tagged accum row of a schema-v5 [`BenchReport`] with the
/// bf16-tagged row at the same `(model, variant, batch, kernel)` point
/// and report the throughput ratios. Reports without the dtype axis
/// (pre-v5 files, axis-less runs) yield no pairs.
pub fn measured_dtype_ratios(report: &crate::benchreport::BenchReport) -> Vec<DtypeRatio> {
    let mut out = Vec::new();
    for e in &report.entries {
        if e.kind != "accum" || e.param_dtype != "f32" || e.median <= 0.0 {
            continue;
        }
        let pair = report.entries.iter().find(|o| {
            o.kind == "accum"
                && o.param_dtype == "bf16"
                && o.model == e.model
                && o.variant == e.variant
                && o.batch == e.batch
                && o.kernel == e.kernel
        });
        if let Some(bf) = pair {
            out.push(DtypeRatio {
                model: e.model.clone(),
                variant: e.variant.clone().unwrap_or_default(),
                batch: e.batch.unwrap_or(0),
                kernel: e.kernel.clone(),
                ratio: bf.median / e.median,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::paper_ladder;

    #[test]
    fn nonprivate_ratio_monotone_in_size() {
        // Fig 5: "For non-private training, throughput increases with
        // model size."
        let m = Tf32Model::default();
        let vits = &paper_ladder()[..5];
        let ratios: Vec<f64> = vits
            .iter()
            .map(|a| m.throughput_ratio(a, ClippingMethod::NonPrivate))
            .collect();
        for w in ratios.windows(2) {
            assert!(w[1] > w[0], "{ratios:?}");
        }
        assert!(ratios.iter().all(|&r| r > 1.0 && r < 4.0), "{ratios:?}");
    }

    #[test]
    fn private_ratio_peaks_at_base() {
        // Fig 5: private gains grow up to Base then decline for
        // Large/Huge ("models that are too small do not gain much, and
        // the larger ones are too expensive").
        let m = Tf32Model::default();
        let vits = &paper_ladder()[..5]; // tiny small base large huge
        let r: Vec<f64> = vits
            .iter()
            .map(|a| m.throughput_ratio(a, ClippingMethod::PerExample))
            .collect();
        let peak = r
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!(peak == 1 || peak == 2, "peak at index {peak}: {r:?}");
        assert!(r[2] > r[4], "base {} must beat huge {}", r[2], r[4]);
        assert!(r.iter().all(|&x| x >= 1.0), "{r:?}");
    }

    #[test]
    fn tf32_never_hurts_in_model() {
        let m = Tf32Model::default();
        for a in paper_ladder() {
            for method in [ClippingMethod::NonPrivate, ClippingMethod::PerExample] {
                assert!(m.throughput_ratio(&a, method) >= 1.0);
            }
        }
    }

    #[test]
    fn measured_dtype_ratios_pair_rows_on_the_full_axis_key() {
        use crate::benchreport::{BenchEntry, BenchReport, SCHEMA_VERSION};
        let row = |kernel: &str, dtype: &str, median: f64| BenchEntry {
            kind: "accum".into(),
            model: "mlp-wide".into(),
            variant: Some("masked".into()),
            batch: Some(16),
            repeats: 3,
            unit: "examples_per_sec".into(),
            median,
            ci_low: median,
            ci_high: median,
            n: 3,
            secs_total: 1.0,
            kernel: kernel.into(),
            param_dtype: dtype.into(),
        };
        let report = BenchReport {
            schema_version: SCHEMA_VERSION,
            backend: "reference".into(),
            seed: 0,
            quick: true,
            models: vec!["mlp-wide".into()],
            clip_methods: Vec::new(),
            kernels: vec!["scalar".into(), "simd".into()],
            param_dtypes: vec!["f32".into(), "bf16".into()],
            sections: None,
            entries: vec![
                row("scalar", "f32", 100.0),
                row("scalar", "bf16", 90.0),
                row("simd", "f32", 250.0),
                row("simd", "bf16", 240.0),
            ],
            workers: None,
            serve_tenants: Vec::new(),
            serve: Vec::new(),
        };
        report.validate().unwrap();
        let ratios = measured_dtype_ratios(&report);
        assert_eq!(ratios.len(), 2, "{ratios:?}");
        // Pairing respects the kernel axis: scalar pairs with scalar.
        assert_eq!(ratios[0].kernel, "scalar");
        assert!((ratios[0].ratio - 0.9).abs() < 1e-12);
        assert_eq!(ratios[1].kernel, "simd");
        assert!((ratios[1].ratio - 0.96).abs() < 1e-12);

        // An axis-less (PJRT-style) report yields no pairs.
        let mut bare = report;
        bare.kernels.clear();
        bare.param_dtypes.clear();
        for e in &mut bare.entries {
            e.kernel.clear();
            e.param_dtype.clear();
        }
        assert!(measured_dtype_ratios(&bare).is_empty());
    }
}
