//! `dpshort` — the launcher for DP-SGD-without-shortcuts.
//!
//! Subcommands:
//!
//! ```text
//! dpshort list                         show models/variants of the active backend
//! dpshort train   [flags]              run DP-SGD (or the baseline) end to end
//! dpshort bench   [flags]              steady-state throughput sweep
//! dpshort plan    [flags]              analytic max-batch memory planner (Fig 3 / Tab 3)
//! dpshort account [flags]              privacy accounting / sigma calibration
//! dpshort audit   [flags]              static plan audit (taint + rule catalog, pre-run)
//! dpshort lint    --source             determinism source lint over rust/src
//! dpshort serve   --jobs FILE.json     multi-tenant DP training service (central budget ledger)
//! dpshort scale   [flags]              multi-GPU scaling simulation (Fig 7 / A.4 / A.5)
//! dpshort report  <fig1|fig2|fig3|table1|table2|table3|fig4|fig5|fig6|figA1|figA2|fig7|figA5|all>
//! ```
//!
//! Backend selection: `--backend reference` forces the pure-Rust
//! reference executor; `--backend pjrt` forces the artifact path. With
//! neither, artifacts are used when present (and the `pjrt` feature is
//! on), falling back to the reference backend so every command works on
//! a fresh offline checkout.

use anyhow::{anyhow, Result};
use dp_shortcuts::analysis::{self, audit_hlo, lint_source, parse_allowlist};
use dp_shortcuts::benchreport::{self, BenchReport, SweepOptions};
use dp_shortcuts::clipping::{clip_method_variant, CLI_CLIP_METHODS};
use dp_shortcuts::coordinator::batcher::BatchingMode;
use dp_shortcuts::coordinator::config::TrainConfig;
use dp_shortcuts::coordinator::sampler::SamplerChoice;
use dp_shortcuts::coordinator::trainer::{config_fingerprint, resolve_sigma, TrainSession};
use dp_shortcuts::fault::{self, FaultPlan};
use dp_shortcuts::privacy::{calibrate_sigma, AccountantKind, RdpAccountant};
use dp_shortcuts::report;
use dp_shortcuts::runtime::{hlo_analysis, Kernel, Runtime};
use dp_shortcuts::serve::{self, BudgetLedger, ServeOptions};
use dp_shortcuts::util::cli::Args;
use std::path::{Path, PathBuf};

const USAGE: &str = "usage: dpshort <list|train|bench|serve|plan|account|scale|report> [--flags]
  common flags: --artifacts DIR (default: artifacts)
                --backend reference|pjrt (default: pjrt if artifacts exist, else reference)
                --threads N (reference-backend accum workers; 0 = auto;
                             wall-clock only, bits never change)
                --kernel scalar|simd|auto (reference-backend inner
                             kernels; scalar and SIMD share the fixed
                             8-lane reduction tree, so this is
                             wall-clock only — bits never change;
                             default auto)
  train/bench:  --model NAME --variant V --batch B --steps N --rate Q
                --dataset N --lr LR --sigma S --epsilon E --delta D
                --seed S --bf16 --naive-mode --eval N --json
                --param-dtype f32|bf16  parameter STORAGE dtype (bf16
                             stores round-to-nearest-even, compute
                             stays f32; changes the trajectory, so it
                             is in the checkpoint fingerprint;
                             --bf16 is shorthand for bf16)
                --clip-method per-example|ghost|mix|bk|nonprivate
                             clipping method (resolves to the lowered
                             accum variant; conflicts with --variant;
                             all methods are bitwise-identical in
                             trajectory — they move wall-clock/memory
                             traffic only)
  train:        --workers N  data-parallel worker sessions (wall-clock
                             only: the trajectory is bitwise-identical
                             for every N; default 1)
                --load-params FILE  warm-start from saved parameters
                                    (fresh step counter and privacy
                                    accounting; exact resume is the
                                    TrainCheckpoint API)
                --save-params FILE  write the final parameters
                --retries N --retry-backoff-ms MS  per-step recovery
                             budget (a retry replays the SAME Poisson
                             draw and noise tuple; wall-clock only,
                             DESIGN.md §11)
                --autosave N        checkpoint every N steps (atomic
                             temp-file+rename write with a content
                             checksum) into --checkpoint-dir DIR
                             (default checkpoints)
                --resume-latest     resume from the newest valid
                             checkpoint in --checkpoint-dir; torn,
                             corrupt, or mismatched files are skipped
                             with typed errors
                --inject-faults SPEC  deterministic fault injection:
                             comma-separated KIND@sSTEP[.rRANK][.cCALL]
                             [.msMILLIS] with KIND one of accum-err|
                             apply-err|panic|slow|ckpt-truncate|
                             ckpt-flip, or random.seedN.countM
  bench:        accum/apply throughput sweep -> BENCH_throughput.json
                --repeats R --quick --out FILE (default BENCH_throughput.json)
                --model/--variant/--batch restrict the sweep
                --workers LIST  worker counts for the data-parallel
                                training-throughput scaling sweep
                                (default 1,2,4; schema v3 `workers`
                                rows keyed by (model, clip_method,
                                workers))
                --clip-methods LIST  clip methods for the scaling sweep
                                (default per-example,ghost)
                --kernels LIST  kernel axes for the reference sweep
                                (scalar,simd; default auto — one axis);
                                schema v5 rows carry a `kernel` tag
                --param-dtypes LIST  param-storage dtype axes for the
                                reference sweep (f32,bf16; default
                                f32); rows carry a `param_dtype` tag
                --check FILE  validate an emitted file's schema and exit
                --serve  synthetic multi-tenant load sweep instead of the
                                accum/apply sweep -> schema v4 `serve` rows
                                keyed by (tenants, max_concurrent) with
                                aggregate ex/s + per-slice p50/p95/p99;
                                --tenants N (default 3),
                                --max-concurrent LIST (default 1,2,N),
                                --steps-per-slice N, --memory-budget-bytes B
  serve:        multi-tenant DP training service over the shared backend
                --jobs FILE.json  job manifest (required); every job is
                             audited at submission — Deny plans are
                             rejected before a single step runs
                --max-concurrent N  resident-session cap (default 2;
                             wall-clock/memory only, bits never change)
                --memory-budget-bytes B  analytic residency memory cap
                             per MemModel::peak_bytes (0 = unlimited)
                --steps-per-slice N  scheduler slice length (default 2)
                --ckpt-dir DIR  per-tenant checkpoint namespaces + the
                             ledger snapshot (default serve-ckpts)
                --resume     restore the central ledger snapshot before
                             serving (crash recovery; epsilon is never
                             double-committed)
                --max-slices N  stop (as if crashed) after N slices —
                             the deterministic crash-simulation knob
                --json       machine-readable ServeReport
  train/audit:  --sampler poisson|shuffle  subsampling scheme (shuffle is
                             the studied shortcut; Deny-audited under
                             Poisson accounting)
                --accountant rdp|pld  accountant reporting epsilon
                             (reporting only, never the trajectory)
                --allow-unsound  run past Deny audit diagnostics; the
                             report and checkpoints are stamped unaudited
                --retry-fresh-draw  declare a retry policy that re-draws
                             the mask/noise on step retry; never
                             executed — the audit denies it
                             (retry.fresh-draw)
  account:      --rate Q --steps N --delta D [--sigma S | --epsilon E]
  audit:        static plan audit, no example is ever touched
                train-style flags pick the run; --json for the
                machine-readable report; --hlo FILE folds an HLO text
                dump into the materialization/dtype rules;
                --ladder audits every shipped model x clip-method x
                accountant x worker-count combination
  lint:         --source (required) determinism lint over --root
                (default rust/src) with --allowlist
                (default lint-allowlist.txt)
  scale:        --model NAME --gpus LIST (e.g. 1,4,8,16,32,80)
  report:       <figure-or-table id> [--quick]";

fn config_from(args: &Args, rt: &Runtime) -> Result<TrainConfig> {
    let mut c = TrainConfig::default();
    if let Some(m) = args.get("model") {
        c.model = m.to_string();
    } else if !rt.manifest().models.contains_key(&c.model) {
        // No --model and the compiled-in default isn't in this
        // manifest (e.g. reference backend): use its first model.
        if let Some(first) = rt.default_model() {
            c.model = first.to_string();
        }
    }
    if let Some(v) = args.get("variant") {
        c.variant = v.to_string();
    }
    if let Some(method) = args.get("clip-method") {
        if args.get("variant").is_some() {
            return Err(anyhow!(
                "--clip-method and --variant both name the accum graph; pass one"
            ));
        }
        c.variant = clip_method_variant(method)
            .ok_or_else(|| {
                anyhow!(
                    "unknown clip method {method:?} (have: {})",
                    CLI_CLIP_METHODS
                        .iter()
                        .map(|(n, _)| *n)
                        .collect::<Vec<_>>()
                        .join("|")
                )
            })?
            .to_string();
    }
    c.bf16 = args.get_bool("bf16");
    if let Some(d) = args.get("param-dtype") {
        match d {
            "f32" => c.bf16 = false,
            "bf16" => c.bf16 = true,
            other => return Err(anyhow!("unknown param dtype {other:?} (f32|bf16)")),
        }
    }
    if let Some(k) = args.get("kernel") {
        Kernel::parse(k).ok_or_else(|| anyhow!("unknown kernel {k:?} (scalar|simd|auto)"))?;
        c.kernel = k.to_string();
    }
    c.dataset_size = args.get_parse_or("dataset", c.dataset_size).map_err(|e| anyhow!(e))?;
    c.sampling_rate = args.get_parse_or("rate", c.sampling_rate).map_err(|e| anyhow!(e))?;
    c.physical_batch = args.get_parse_or("batch", c.physical_batch).map_err(|e| anyhow!(e))?;
    c.steps = args.get_parse_or("steps", c.steps).map_err(|e| anyhow!(e))?;
    c.lr = args.get_parse_or("lr", c.lr).map_err(|e| anyhow!(e))?;
    c.clip_norm = args.get_parse_or("clip", c.clip_norm).map_err(|e| anyhow!(e))?;
    c.noise_multiplier = args.get_parse("sigma").map_err(|e| anyhow!(e))?;
    c.target_epsilon = args.get_parse_or("epsilon", c.target_epsilon).map_err(|e| anyhow!(e))?;
    c.delta = args.get_parse_or("delta", c.delta).map_err(|e| anyhow!(e))?;
    c.seed = args.get_parse_or("seed", c.seed).map_err(|e| anyhow!(e))?;
    c.eval_examples = args.get_parse_or("eval", c.eval_examples).map_err(|e| anyhow!(e))?;
    c.workers = args.get_parse_or("workers", c.workers).map_err(|e| anyhow!(e))?;
    if let Some(s) = args.get("sampler") {
        c.sampler = SamplerChoice::parse(s)
            .ok_or_else(|| anyhow!("unknown sampler {s:?} (poisson|shuffle)"))?;
    }
    if let Some(a) = args.get("accountant") {
        c.accountant = AccountantKind::parse(a)
            .ok_or_else(|| anyhow!("unknown accountant {a:?} (rdp|pld)"))?;
    }
    c.allow_unsound = args.get_bool("allow-unsound");
    c.retry.max_attempts =
        args.get_parse_or("retries", c.retry.max_attempts).map_err(|e| anyhow!(e))?;
    c.retry.backoff_ms =
        args.get_parse_or("retry-backoff-ms", c.retry.backoff_ms).map_err(|e| anyhow!(e))?;
    c.retry.fresh_draw_on_retry = args.get_bool("retry-fresh-draw");
    if args.get_bool("naive-mode") || c.variant == "naive" {
        c.mode = BatchingMode::Variable;
    }
    Ok(c)
}

/// Resolve the runtime from `--backend`/`--artifacts`/`--threads`/
/// `--kernel` (see module docs). `--threads` wires
/// `ReferenceBackend::with_threads` and `--kernel` the SIMD-vs-scalar
/// inner-kernel choice — both wall-clock knobs only (bits never
/// change) — and both are rejected on the PJRT path, where threading
/// and kernels belong to the PJRT client.
fn load_runtime(args: &Args, artifacts: &str) -> Result<Runtime> {
    let threads: usize = args.get_parse_or("threads", 0).map_err(|e| anyhow!(e))?;
    let kernel = match args.get("kernel") {
        Some(k) => Some(
            Kernel::parse(k).ok_or_else(|| anyhow!("unknown kernel {k:?} (scalar|simd|auto)"))?,
        ),
        None => None,
    };
    match args.get("backend") {
        Some("reference") => Ok(Runtime::reference_with_options(
            0,
            threads,
            kernel.unwrap_or_else(Kernel::auto),
        )),
        Some("pjrt") if threads > 0 => {
            Err(anyhow!("--threads applies to the reference backend only"))
        }
        Some("pjrt") if kernel.is_some() => {
            Err(anyhow!("--kernel applies to the reference backend only"))
        }
        Some("pjrt") => Runtime::load(artifacts),
        Some(other) => Err(anyhow!("unknown backend {other:?} (reference|pjrt)")),
        None => Runtime::auto_with_options(artifacts, threads, kernel),
    }
}

fn cmd_list(rt: &Runtime) -> Result<()> {
    println!("backend: {}", rt.backend_name());
    println!("{:<12} {:>10} {:>6}  variants x batches", "model", "params", "image");
    for (name, m) in &rt.manifest().models {
        println!(
            "{:<12} {:>10} {:>4}px  {}",
            name,
            m.n_params,
            m.image,
            m.variants()
                .iter()
                .map(|v| format!("{v}@{:?}", m.accum_batches(v, "f32")))
                .collect::<Vec<_>>()
                .join(" ")
        );
    }
    Ok(())
}

fn cmd_train(rt: &Runtime, args: &Args) -> Result<()> {
    let cfg = config_from(args, rt)?;
    // Fault injection wraps the backend BEFORE any session opens, so
    // injection rank ids line up with the trainer's open order.
    let fault_plan: Option<std::sync::Arc<FaultPlan>> = match args.get("inject-faults") {
        Some(spec) => Some(std::sync::Arc::new(FaultPlan::from_spec(
            spec,
            cfg.steps,
            cfg.workers.max(1),
        )?)),
        None => None,
    };
    let faulted;
    let rt = match &fault_plan {
        Some(plan) => {
            faulted = fault::faulty_runtime(rt, std::sync::Arc::clone(plan));
            &faulted
        }
        None => rt,
    };
    let autosave: u64 = args.get_parse_or("autosave", 0).map_err(|e| anyhow!(e))?;
    let ckpt_dir = PathBuf::from(args.get_or("checkpoint-dir", "checkpoints"));
    println!(
        "train: backend={} model={} variant={} mode={:?} B={} q={} steps={} E[L]={} workers={}",
        rt.backend_name(),
        cfg.model,
        cfg.variant,
        cfg.mode,
        cfg.physical_batch,
        cfg.sampling_rate,
        cfg.steps,
        cfg.expected_logical_batch(),
        cfg.workers.max(1)
    );
    // Step-driven session: the same hot loop Trainer::run wraps, but
    // with the checkpoint seam exposed for --load-params/--save-params
    // and the crash-consistent --autosave/--resume-latest store.
    // `--resume-latest`: scan for the newest checkpoint that survives
    // the typed validation chain, surfacing every rejected file.
    let mut start = None;
    if args.get_bool("resume-latest") {
        let fingerprint = config_fingerprint(&cfg, resolve_sigma(&cfg)?);
        let scan = fault::latest_valid(&ckpt_dir, &fingerprint)?;
        for (path, err) in &scan.skipped {
            eprintln!("resume-latest: skipping {}: {err}", path.display());
        }
        match scan.found {
            Some((path, ckpt)) => {
                eprintln!("resuming from {} (step {})", path.display(), ckpt.step);
                start = Some(ckpt);
            }
            None => eprintln!(
                "resume-latest: no valid checkpoint in {}; starting fresh",
                ckpt_dir.display()
            ),
        }
    }
    let mut session = match (start, &fault_plan) {
        (Some(ckpt), Some(plan)) => TrainSession::resume_with_faults(
            rt,
            cfg.clone(),
            ckpt,
            std::sync::Arc::clone(plan),
        )?,
        (Some(ckpt), None) => TrainSession::resume(rt, cfg.clone(), ckpt)?,
        (None, Some(plan)) => {
            TrainSession::with_faults(rt, cfg.clone(), std::sync::Arc::clone(plan))?
        }
        (None, None) => TrainSession::new(rt, cfg.clone())?,
    };
    if let Some(p) = args.get("load-params") {
        let params = session.model().load_params(Path::new(p))?;
        session.write_params(params)?;
        eprintln!(
            "warm start from {p}: step counter and privacy accounting begin fresh \
             (exact resume is the TrainCheckpoint API)"
        );
    }
    while !session.done() {
        session.step()?;
        if autosave > 0 && session.step_index() % autosave == 0 {
            let ckpt = session.checkpoint()?;
            let path = fault::write_checkpoint(&ckpt_dir, &ckpt, fault_plan.as_deref())?;
            eprintln!("autosaved {}", path.display());
        }
    }
    if let Some(p) = args.get("save-params") {
        // The session's own checkpoint seam: read_params is the exact
        // post-training state (finish() only evaluates after this).
        session.model().save_params(&session.read_params()?, Path::new(p))?;
        eprintln!("saved params to {p}");
    }
    let rep = session.finish()?;
    if args.get_bool("json") {
        println!("{}", rep.to_json()?);
        return Ok(());
    }
    if rep.unaudited {
        eprintln!(
            "WARNING: this run executed past Deny audit diagnostics (--allow-unsound); \
             the reported epsilon carries no static-audit backing"
        );
    }
    if !rep.recovery_events.is_empty() {
        println!("recovery events ({}):", rep.recovery_events.len());
        for e in &rep.recovery_events {
            let group = e.group.map(|g| format!(" group {g}")).unwrap_or_default();
            println!("  step {:>3} rank {}{group}: {}: {}", e.step, e.rank, e.action, e.detail);
        }
        println!(
            "worker pool: finished with {} of {} sessions (bitwise-identical by the \
             fixed-tree contract)",
            rep.final_workers,
            cfg.workers.max(1)
        );
    }
    if cfg.is_private() {
        println!(
            "privacy: sigma={:.4}  spent eps={:.3} at delta={:.2e} ({} accountant)",
            rep.noise_multiplier, rep.epsilon_spent, rep.delta, rep.accountant
        );
    }
    for s in &rep.steps {
        println!(
            "  step {:>3}: |L|={:<5} phys={:<3} computed={:<5} loss={:.4}",
            s.step, s.logical_batch, s.physical_batches, s.computed_examples, s.loss
        );
    }
    let t = rep.sections;
    println!(
        "sections (s): sampling={:.3} data={:.3} accum={:.3} apply={:.3} compile={:.3}",
        t.sampling, t.data, t.accum, t.apply, t.compile
    );
    println!(
        "throughput: {:.1} ex/s (real), {:.1} ex/s (incl. Alg.2 padding)",
        rep.throughput, rep.computed_throughput
    );
    if let Some(s) = &rep.accum_throughput {
        println!(
            "accum throughput: aggregate {:.1} ex/s, median {:.1} ex/s (95% CI [{:.1}, {:.1}], n={})",
            rep.accum_throughput_aggregate, s.median, s.ci_low, s.ci_high, s.n
        );
    }
    if let (Some(l), Some(a)) = (rep.eval_loss, rep.eval_accuracy) {
        println!(
            "eval: loss={l:.4} accuracy={a:.4} (over {} of {} requested examples)",
            rep.eval_covered, cfg.eval_examples
        );
    }
    if !rep.compiles.is_empty() {
        println!("compiles ({}):", rep.compiles.len());
        for (p, s) in &rep.compiles {
            println!("  {p}: {s:.2}s");
        }
    }
    Ok(())
}

/// The accum/apply throughput sweep: runs on the active backend, prints
/// a human summary, and writes the machine-readable
/// `BENCH_throughput.json` (schema in `benchreport`, DESIGN.md §6) so
/// the perf trajectory is recorded across PRs.
fn cmd_bench(rt: &Runtime, args: &Args) -> Result<()> {
    if args.get_bool("serve") {
        return cmd_bench_serve(rt, args);
    }
    let quick = args.get_bool("quick");
    let mut opts = SweepOptions::new(quick);
    opts.model = args.get("model").map(str::to_string);
    opts.variant = args.get("variant").map(str::to_string);
    opts.batch = args.get_parse("batch").map_err(|e| anyhow!(e))?;
    opts.seed = args.get_parse_or("seed", opts.seed).map_err(|e| anyhow!(e))?;
    opts.repeats = args.get_parse_or("repeats", opts.repeats).map_err(|e| anyhow!(e))?;
    if let Some(list) = args.get("workers") {
        opts.worker_counts = list
            .split(',')
            .map(|s| s.trim().parse::<usize>().map_err(|e| anyhow!("bad worker count: {e}")))
            .collect::<Result<_>>()?;
    }
    if let Some(list) = args.get("clip-methods") {
        opts.clip_methods = list.split(',').map(|s| s.trim().to_string()).collect();
    } else if let Some(method) = args.get("clip-method") {
        // The singular train-style flag restricts the bench scaling
        // sweep to that one method (it must not be silently ignored).
        opts.clip_methods = vec![method.to_string()];
    }
    if let Some(list) = args.get("kernels") {
        opts.kernels = list.split(',').map(|s| s.trim().to_string()).collect();
    } else if let Some(k) = args.get("kernel") {
        // The singular flag restricts the sweep to that one kernel axis
        // (the runtime handed to us was already built with it, but
        // run_sweep rebuilds per axis, so it must be named here too).
        opts.kernels = vec![k.to_string()];
    }
    if let Some(list) = args.get("param-dtypes") {
        opts.param_dtypes = list.split(',').map(|s| s.trim().to_string()).collect();
    } else if let Some(d) = args.get("param-dtype") {
        opts.param_dtypes = vec![d.to_string()];
    } else if args.get_bool("bf16") {
        opts.param_dtypes = vec!["bf16".to_string()];
    }
    opts.threads = args.get_parse_or("threads", 0).map_err(|e| anyhow!(e))?;
    let report = benchreport::run_sweep(rt, &opts)?;
    // Axis tags ([kernel/dtype]) appear only on reference-backend
    // schema-v5 rows; PJRT rows stay axis-less.
    let axis = |kernel: &str, dtype: &str| {
        if kernel.is_empty() && dtype.is_empty() {
            String::new()
        } else {
            format!(" [{kernel}/{dtype}]")
        }
    };
    for e in &report.entries {
        match e.kind.as_str() {
            "accum" => println!(
                "{} {} B={}{}: median {:.1} ex/s (95% CI [{:.1}, {:.1}], n={})",
                e.model,
                e.variant.as_deref().unwrap_or("?"),
                e.batch.unwrap_or(0),
                axis(&e.kernel, &e.param_dtype),
                e.median,
                e.ci_low,
                e.ci_high,
                e.n
            ),
            _ => println!(
                "{} apply{}: median {:.1} calls/s (95% CI [{:.1}, {:.1}], n={})",
                e.model,
                axis(&e.kernel, &e.param_dtype),
                e.median,
                e.ci_low,
                e.ci_high,
                e.n
            ),
        }
    }
    if let Some(s) = &report.sections {
        println!(
            "sections (s): sampling={:.3} data={:.3} accum={:.3} apply={:.3} compile={:.3}",
            s.sampling, s.data, s.accum, s.apply, s.compile
        );
    }
    if let Some(curve) = &report.workers {
        println!("data-parallel scaling (wall clock, bitwise-identical results):");
        for w in curve {
            // Speedup is relative to the same (model, clip method) at
            // one worker — the v3 curve carries one row per
            // (model, clip_method, workers).
            let base = curve
                .iter()
                .find(|c| c.workers == 1 && c.model == w.model && c.clip_method == w.clip_method)
                .map(|c| c.throughput);
            let speedup = base
                .map(|b| format!("  {:.2}x vs 1 worker", w.throughput / b))
                .unwrap_or_default();
            println!(
                "  {:<12} {:<12} workers={:<3} {:>10.1} ex/s over {} steps{speedup}",
                w.model, w.clip_method, w.workers, w.throughput, w.steps
            );
        }
    }
    let out = PathBuf::from(args.get_or("out", benchreport::DEFAULT_OUT));
    report.write(&out)?;
    println!(
        "wrote {} ({} entries, backend {})",
        out.display(),
        report.entries.len(),
        report.backend
    );
    Ok(())
}

/// `dpshort bench --serve`: the synthetic multi-tenant load sweep —
/// admit a generated manifest once, serve it at every requested
/// `--max-concurrent` level, and write schema-v4 `serve` rows keyed by
/// `(tenants, max_concurrent)` with aggregate examples/sec and the
/// per-slice p50/p95/p99 latency tail.
fn cmd_bench_serve(rt: &Runtime, args: &Args) -> Result<()> {
    let quick = args.get_bool("quick");
    let scratch =
        std::env::temp_dir().join(format!("dpshort_bench_serve_{}", std::process::id()));
    let mut opts = benchreport::ServeSweepOptions::new(quick, scratch.clone());
    opts.tenants = args.get_parse_or("tenants", opts.tenants).map_err(|e| anyhow!(e))?;
    opts.steps = args.get_parse_or("steps", opts.steps).map_err(|e| anyhow!(e))?;
    opts.steps_per_slice =
        args.get_parse_or("steps-per-slice", opts.steps_per_slice).map_err(|e| anyhow!(e))?;
    opts.seed = args.get_parse_or("seed", opts.seed).map_err(|e| anyhow!(e))?;
    opts.memory_budget_bytes =
        args.get_parse_or("memory-budget-bytes", 0.0).map_err(|e| anyhow!(e))?;
    opts.concurrency = match args.get("max-concurrent") {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().parse::<usize>().map_err(|e| anyhow!("bad concurrency: {e}")))
            .collect::<Result<_>>()?,
        // The default ladder: serial, pairwise, and fully resident.
        None => vec![1, 2, opts.tenants],
    };
    let report = benchreport::run_serve_sweep(rt, &opts);
    let _ = std::fs::remove_dir_all(&scratch);
    let report = report?;
    println!("serve load sweep ({} tenants, backend {}):", opts.tenants, report.backend);
    for s in &report.serve {
        println!(
            "  max_concurrent={:<3} {:>10.1} ex/s over {} slices, {} evictions, \
             slice p50/p95/p99 = {:.4}/{:.4}/{:.4} s",
            s.max_concurrent,
            s.throughput,
            s.slices,
            s.evictions,
            s.p50_latency,
            s.p95_latency,
            s.p99_latency
        );
    }
    let out = PathBuf::from(args.get_or("out", benchreport::DEFAULT_OUT));
    report.write(&out)?;
    println!(
        "wrote {} ({} serve rows, backend {})",
        out.display(),
        report.serve.len(),
        report.backend
    );
    Ok(())
}

/// `dpshort serve --jobs FILE.json`: the multi-tenant training
/// service. Jobs are audited (and Deny-rejected) at submission; the
/// cooperative scheduler time-slices admitted sessions under the
/// residency caps; the central ledger commits epsilon strictly after
/// each durable slice and hard-stops any tenant the step before its
/// declared budget would be exceeded.
fn cmd_serve(rt: &Runtime, args: &Args) -> Result<()> {
    let jobs_path =
        args.get("jobs").ok_or_else(|| anyhow!("serve needs --jobs FILE.json\n{USAGE}"))?;
    let jobs = serve::load_jobs(Path::new(jobs_path))?;
    let (tenants, rejections) = serve::admit(rt, &jobs)?;
    for r in &rejections {
        eprintln!("rejected {:?}: {}", r.name, r.reason);
    }
    if tenants.is_empty() {
        return Err(anyhow!(
            "no jobs admitted ({} of {} rejected)",
            rejections.len(),
            jobs.tenants.len()
        ));
    }
    let opts = ServeOptions {
        max_concurrent: args.get_parse_or("max-concurrent", 2).map_err(|e| anyhow!(e))?,
        memory_budget_bytes: args
            .get_parse_or("memory-budget-bytes", 0.0)
            .map_err(|e| anyhow!(e))?,
        steps_per_slice: args.get_parse_or("steps-per-slice", 2).map_err(|e| anyhow!(e))?,
        ckpt_root: PathBuf::from(args.get_or("ckpt-dir", "serve-ckpts")),
        max_slices: args.get_parse("max-slices").map_err(|e| anyhow!(e))?,
    };
    // --resume restores the persisted ledger (committed epsilon
    // survives even if a checkpoint went missing); without it the
    // ledger still reconciles against each tenant's newest valid
    // checkpoint, so a crashed serve never double-commits either way.
    let mut ledger = if args.get_bool("resume") {
        BudgetLedger::load(&opts.ckpt_root)?.unwrap_or_else(BudgetLedger::new)
    } else {
        BudgetLedger::new()
    };
    let mut report = serve::run_serve(rt, &tenants, &mut ledger, &opts)?;
    report.rejections = rejections;
    if args.get_bool("json") {
        println!("{}", report.to_json()?);
        return Ok(());
    }
    println!(
        "serve: {} admitted, {} rejected; max_concurrent={} steps_per_slice={} ckpt={}",
        report.outcomes.len(),
        report.rejections.len(),
        opts.max_concurrent,
        opts.steps_per_slice,
        opts.ckpt_root.display()
    );
    for o in &report.outcomes {
        println!(
            "  {:<14} {:<16} steps={:<5} eps {:.4} of {:.4} budget, {} evictions",
            o.name, o.status, o.steps_done, o.epsilon_committed, o.budget_epsilon, o.evictions
        );
    }
    if let Some(q) = report.slice_latency {
        println!(
            "slices: {} total, {} evictions; latency p50/p95/p99 = {:.4}/{:.4}/{:.4} s",
            report.slices.len(),
            report.evictions,
            q.p50,
            q.p95,
            q.p99
        );
    }
    println!("aggregate throughput: {:.1} ex/s", report.aggregate_examples_per_sec);
    if report.interrupted {
        println!(
            "interrupted by --max-slices: every completed slice is checkpointed and \
             committed; rerun with --resume to continue"
        );
    }
    Ok(())
}

/// `dpshort bench --check FILE`: schema-validate an emitted report
/// (the CI smoke gate) without running any benchmark.
fn cmd_bench_check(path: &str) -> Result<()> {
    let report = BenchReport::check_file(Path::new(path))?;
    println!(
        "{path}: schema v{} ok ({} entries, backend {})",
        report.schema_version,
        report.entries.len(),
        report.backend
    );
    Ok(())
}

fn cmd_account(args: &Args) -> Result<()> {
    let q: f64 = args.get_parse_or("rate", 0.5).map_err(|e| anyhow!(e))?;
    let steps: u64 = args.get_parse_or("steps", 4).map_err(|e| anyhow!(e))?;
    let delta: f64 = args.get_parse_or("delta", 2.04e-5).map_err(|e| anyhow!(e))?;
    let acc = RdpAccountant::default();
    if let Some(sigma) = args.get_parse::<f64>("sigma").map_err(|e| anyhow!(e))? {
        let eps = acc.epsilon(q, sigma, steps, delta);
        let order = acc.optimal_order(q, sigma, steps, delta);
        println!("eps = {eps:.4} at delta={delta:.2e} (optimal RDP order {order})");
    } else {
        let eps: f64 = args.get_parse_or("epsilon", 8.0).map_err(|e| anyhow!(e))?;
        let sigma = calibrate_sigma(eps, delta, q, steps).map_err(|e| anyhow!(e))?;
        println!("sigma = {sigma:.4} reaches eps={eps} at delta={delta:.2e} (q={q}, T={steps})");
    }
    Ok(())
}

/// `dpshort audit`: statically audit the configured run before any
/// example is touched — lower the plan exactly as `TrainSession::new`
/// would, run the taint/rule pass, and print structured diagnostics.
/// Exit is non-zero when any Deny-severity finding survives.
fn cmd_audit(rt: &Runtime, args: &Args) -> Result<()> {
    if args.get_bool("ladder") {
        return cmd_audit_ladder(rt, args);
    }
    let cfg = config_from(args, rt)?;
    let model = rt.model(&cfg.model)?;
    let sigma = resolve_sigma(&cfg)?;
    let mut report = analysis::audit_run(model.meta(), rt.manifest().seed, &cfg, sigma)?;
    if let Some(hlo) = args.get("hlo") {
        let stats = hlo_analysis::analyze_file(Path::new(hlo))?;
        report.push_all(audit_hlo(
            &stats,
            cfg.physical_batch,
            model.meta().n_params,
            &cfg.variant,
        ));
    }
    report.validate()?;
    if args.get_bool("json") {
        println!("{}", report.to_json()?);
    } else {
        println!(
            "audit: model={} variant={} sampler={} accountant={} workers={} steps={} sigma={:.4}",
            report.model,
            report.variant,
            report.sampler,
            report.accountant,
            report.workers,
            report.steps,
            report.sigma
        );
        for d in &report.diagnostics {
            println!("  [{}] {} at {}: {}", d.severity, d.rule, d.location, d.message);
        }
    }
    let (deny, warn, info) = report.counts();
    if report.is_clean() {
        // Keep --json output strictly machine-readable.
        if !args.get_bool("json") {
            println!("audit clean: 0 deny, {warn} warn, {info} info");
        }
        Ok(())
    } else {
        Err(anyhow!(
            "audit rejected the plan: {deny} deny ({}), {warn} warn; \
             `dpshort train --allow-unsound` runs it anyway with an unaudited stamp",
            report.deny_rules().join(", ")
        ))
    }
}

/// `dpshort audit --ladder`: every shipped model x clip method x
/// accountant x worker count must lower to a Deny-free plan (the CI
/// gate that keeps the catalog and the trainer in lockstep).
fn cmd_audit_ladder(rt: &Runtime, args: &Args) -> Result<()> {
    let models: Vec<String> = rt.manifest().models.keys().cloned().collect();
    let mut audited = 0usize;
    let mut rejected = Vec::new();
    for model_name in &models {
        let model = rt.model(model_name)?;
        for (method, variant) in CLI_CLIP_METHODS {
            for accountant in [AccountantKind::Rdp, AccountantKind::Pld] {
                for workers in [1usize, 2] {
                    let cfg = TrainConfig {
                        model: model_name.clone(),
                        variant: (*variant).to_string(),
                        accountant,
                        workers,
                        ..config_from(args, rt)?
                    };
                    let sigma = resolve_sigma(&cfg)?;
                    let report =
                        analysis::audit_run(model.meta(), rt.manifest().seed, &cfg, sigma)?;
                    report.validate()?;
                    audited += 1;
                    if !report.is_clean() {
                        rejected.push(format!(
                            "{model_name}/{method}/{}/w{workers}: {}",
                            accountant.as_str(),
                            report.deny_rules().join(", ")
                        ));
                    }
                }
            }
        }
    }
    if rejected.is_empty() {
        println!(
            "ladder audit clean: {audited} combinations over {} models",
            models.len()
        );
        Ok(())
    } else {
        Err(anyhow!(
            "ladder audit rejected {} combinations:\n  {}",
            rejected.len(),
            rejected.join("\n  ")
        ))
    }
}

/// `dpshort lint --source`: the determinism lint over the crate source
/// (see `analysis::source_lint`). Exit is non-zero on any finding that
/// survives the allowlist.
fn cmd_lint(args: &Args) -> Result<()> {
    if !args.get_bool("source") {
        return Err(anyhow!("lint needs --source (the only implemented pass)"));
    }
    let root = args.get_or("root", "rust/src").to_string();
    let allow_path = args.get_or("allowlist", "lint-allowlist.txt").to_string();
    // A missing allowlist is an empty one (fresh checkouts stay usable).
    let allow = match std::fs::read_to_string(&allow_path) {
        Ok(text) => parse_allowlist(&text),
        Err(_) => Vec::new(),
    };
    let rep = lint_source(Path::new(&root), &allow)?;
    for f in &rep.findings {
        println!("  [{}] {}:{}: {}", f.rule, f.path, f.line, f.text.trim());
        println!("      {}", f.why);
    }
    println!(
        "lint: {} files, {} findings, {} allowlisted, {} inline-suppressed",
        rep.files_scanned,
        rep.findings.len(),
        rep.allowed,
        rep.suppressed
    );
    if rep.findings.is_empty() {
        Ok(())
    } else {
        Err(anyhow!(
            "{} lint finding(s); fix them or add a justified entry to {allow_path}",
            rep.findings.len()
        ))
    }
}

fn cmd_scale(rt: &Runtime, args: &Args) -> Result<()> {
    let gpus: Vec<usize> = args
        .get_or("gpus", "1,2,4,8,16,32,64,80")
        .split(',')
        .map(|s| s.trim().parse::<usize>().map_err(|e| anyhow!("bad gpu count: {e}")))
        .collect::<Result<_>>()?;
    let default_model = rt
        .default_model()
        .ok_or_else(|| anyhow!("empty manifest"))?
        .to_string();
    let model = args.get_or("model", &default_model);
    report::print_scaling_study(rt, model, &gpus)
}

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(
        &raw,
        &[
            "bf16",
            "naive-mode",
            "quick",
            "help",
            "json",
            "allow-unsound",
            "source",
            "ladder",
            "resume-latest",
            "retry-fresh-draw",
            "serve",
            "resume",
        ],
    )
    .map_err(|e| anyhow!(e))?;
    if args.positional.is_empty() || args.get_bool("help") {
        println!("{USAGE}");
        return Ok(());
    }
    let artifacts = args.get_or("artifacts", "artifacts").to_string();
    let cmd = args.positional[0].as_str();

    // Commands that don't need the runtime:
    match cmd {
        "account" => return cmd_account(&args),
        "lint" => return cmd_lint(&args),
        "bench" if args.get("check").is_some() => {
            return cmd_bench_check(args.get("check").unwrap())
        }
        "plan" => {
            let budget_gb: f64 =
                args.get_parse_or("budget-gb", 40.0).map_err(|e| anyhow!(e))?;
            report::print_max_batch_table(budget_gb * 1e9);
            return Ok(());
        }
        _ => {}
    }
    let rt = load_runtime(&args, &artifacts)?;
    match cmd {
        "list" => cmd_list(&rt),
        "train" => cmd_train(&rt, &args),
        "audit" => cmd_audit(&rt, &args),
        "bench" => cmd_bench(&rt, &args),
        "serve" => cmd_serve(&rt, &args),
        "scale" => cmd_scale(&rt, &args),
        "report" => {
            let what = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            report::run(&rt, what, args.get_bool("quick"))
        }
        other => Err(anyhow!("unknown command {other:?}\n{USAGE}")),
    }
}
