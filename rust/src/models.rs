//! Model architecture tables: the paper's ladder (Table 1) at full scale
//! for the analytic studies (memory planner, mix-ghost decision rule,
//! FLOP/roofline models), the **layer IR** ([`LayerSpec`]) every
//! executable model is described in, and the CPU-executable ladder
//! ([`cpu_ladder`]) the reference backend runs end-to-end.
//!
//! Paper-scale dims follow the standard ViT (Dosovitskiy et al. 2021,
//! timm checkpoints) and BiT-ResNet (Kolesnikov et al. 2020) recipes at
//! 224x224 input; parameter counts are validated against Table 1 in unit
//! tests.

/// Element-wise activation of a dense layer in the executable layer IR.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    /// Identity (the head layer feeding softmax-xent is always `None`).
    None,
    /// `max(0, x)` — the only nonlinearity the CPU ladder needs.
    Relu,
}

impl Activation {
    /// Manifest-string form ("none" | "relu").
    pub fn as_str(&self) -> &'static str {
        match self {
            Activation::None => "none",
            Activation::Relu => "relu",
        }
    }

    /// Parse the manifest-string form.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "none" => Some(Activation::None),
            "relu" => Some(Activation::Relu),
            _ => None,
        }
    }
}

/// The structural kind of one layer in the executable IR. Every kind
/// maps a flat input of width `d_in` to a flat output of width `d_out`;
/// the kind fixes how the widths factor (channels x spatial for convs,
/// tokens x features for attention) and which parameters the layer
/// owns. Flat parameter layouts per kind live in
/// `runtime::layers::LayerPlan`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// `z = W a + b`, `W: [d_out, d_in]` row-major.
    Dense,
    /// Channels-first 2-D convolution: input `[c_in, h_in, w_in]`,
    /// kernel `[c_out, c_in, kh, kw]`, per-channel bias, zero padding
    /// `pad` on every side, floor output size (`conv_out_hw`).
    Conv2d {
        c_in: usize,
        h_in: usize,
        w_in: usize,
        c_out: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
    },
    /// LayerNorm over the whole feature vector (`d_in == d_out`):
    /// `z = gamma * xhat + beta`, `xhat = (x - mean) * rsqrt(var + eps)`.
    LayerNorm,
    /// Single-head scaled-dot-product attention over `t` tokens of
    /// width `d_model` (`d_in == d_out == t * d_model`): q/k/v
    /// projections to `d_head`, softmax(q k^T / sqrt(d_head)) v, then an
    /// output projection back to `d_model`.
    Attention { t: usize, d_model: usize, d_head: usize },
}

impl LayerKind {
    /// Manifest-string discriminator.
    pub fn as_str(&self) -> &'static str {
        match self {
            LayerKind::Dense => "dense",
            LayerKind::Conv2d { .. } => "conv2d",
            LayerKind::LayerNorm => "layernorm",
            LayerKind::Attention { .. } => "attention",
        }
    }
}

/// Floor-semantics convolution output size (one axis): input `n`,
/// kernel `k`, stride `s`, padding `p` on both sides.
pub fn conv_out(n: usize, k: usize, s: usize, p: usize) -> usize {
    (n + 2 * p - k) / s + 1
}

/// One layer of the executable layer IR: a [`LayerKind`] between flat
/// widths `d_in -> d_out`, followed by an element-wise [`Activation`].
/// A model is a chain of these; the last layer must be a `Dense` with
/// `Activation::None` and its `d_out` is the class count — the
/// softmax-xent head consumes its logits directly (see
/// `runtime::layers::LayerPlan`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerSpec {
    /// Flat input width (first layer: the flattened image dim `H*W*C`,
    /// channels-first for convs).
    pub d_in: usize,
    /// Flat output width (last layer: `num_classes`).
    pub d_out: usize,
    /// Element-wise activation applied to the layer output.
    pub activation: Activation,
    /// Structural kind (dense / conv2d / layernorm / attention).
    pub kind: LayerKind,
}

impl LayerSpec {
    /// Dense layer with no activation (head layers).
    pub fn dense(d_in: usize, d_out: usize) -> Self {
        Self { d_in, d_out, activation: Activation::None, kind: LayerKind::Dense }
    }

    /// Dense layer followed by ReLU (hidden layers).
    pub fn dense_relu(d_in: usize, d_out: usize) -> Self {
        Self { d_in, d_out, activation: Activation::Relu, kind: LayerKind::Dense }
    }

    /// Channels-first conv2d on a square `side x side` input with a
    /// square `k x k` kernel (rectangular shapes construct the
    /// [`LayerKind::Conv2d`] fields directly).
    pub fn conv2d(
        c_in: usize,
        side: usize,
        c_out: usize,
        k: usize,
        stride: usize,
        pad: usize,
        activation: Activation,
    ) -> Self {
        let out = conv_out(side, k, stride, pad);
        Self {
            d_in: c_in * side * side,
            d_out: c_out * out * out,
            activation,
            kind: LayerKind::Conv2d {
                c_in,
                h_in: side,
                w_in: side,
                c_out,
                kh: k,
                kw: k,
                stride,
                pad,
            },
        }
    }

    /// LayerNorm over a width-`d` feature vector (gamma + beta).
    pub fn layernorm(d: usize) -> Self {
        Self { d_in: d, d_out: d, activation: Activation::None, kind: LayerKind::LayerNorm }
    }

    /// Single-head attention over `t` tokens of width `d_model`.
    pub fn attention(t: usize, d_model: usize, d_head: usize) -> Self {
        let d = t * d_model;
        Self {
            d_in: d,
            d_out: d,
            activation: Activation::None,
            kind: LayerKind::Attention { t, d_model, d_head },
        }
    }

    /// Flat parameters of this layer (layout: `runtime::layers`).
    pub fn params(&self) -> usize {
        match self.kind {
            LayerKind::Dense => self.d_in * self.d_out + self.d_out,
            LayerKind::Conv2d { c_in, c_out, kh, kw, .. } => c_out * c_in * kh * kw + c_out,
            LayerKind::LayerNorm => 2 * self.d_out,
            // Wq/Wk/Wv: [d_head, d_model] + bias, Wo: [d_model, d_head]
            // + bias.
            LayerKind::Attention { d_model, d_head, .. } => {
                3 * (d_model * d_head + d_head) + d_model * d_head + d_model
            }
        }
    }

    /// Forward multiply-accumulates per example. Mirrors the analytic
    /// counts in `python/compile/vit.py` / `resnet.py` (convs via their
    /// im2col view, attention as qkv + QK^T + AV + proj; layernorm
    /// counts its two element-wise multiplies) — cross-checked against
    /// those formulas in `rust/tests/layered_models.rs`.
    pub fn macs(&self) -> usize {
        match self.kind {
            LayerKind::Dense => self.d_in * self.d_out,
            LayerKind::Conv2d { c_in, h_in, w_in, c_out, kh, kw, stride, pad } => {
                let t = conv_out(h_in, kh, stride, pad) * conv_out(w_in, kw, stride, pad);
                t * c_in * kh * kw * c_out
            }
            LayerKind::LayerNorm => 2 * self.d_out,
            LayerKind::Attention { t, d_model, d_head } => {
                // qkv (3) + output projection (1), then QK^T + AV.
                4 * t * d_model * d_head + 2 * t * t * d_head
            }
        }
    }

    /// The ghost-clipping view of this layer for the mix-ghost decision
    /// rule ([`crate::clipping::mix_ghost_choice`]): dense layers have
    /// effective sequence length 1, convs their im2col view (`t` spatial
    /// positions x `c_in*kh*kw` unfolded patch), attention the fused qkv
    /// projection over `t` tokens (the decision-dominant linear, as in
    /// `python/compile/vit.py`), layernorm a trivially-ghost affine (its
    /// per-example norm is O(d) either way).
    pub fn linear_dims(&self) -> LinearDims {
        match self.kind {
            LayerKind::Dense => LinearDims { t: 1, d_in: self.d_in, d_out: self.d_out },
            LayerKind::Conv2d { c_in, h_in, w_in, c_out, kh, kw, stride, pad } => LinearDims {
                t: conv_out(h_in, kh, stride, pad) * conv_out(w_in, kw, stride, pad),
                d_in: c_in * kh * kw,
                d_out: c_out,
            },
            LayerKind::LayerNorm => LinearDims { t: 1, d_in: 1, d_out: 2 * self.d_out },
            LayerKind::Attention { t, d_model, d_head } => {
                LinearDims { t, d_in: d_model, d_out: 3 * d_head }
            }
        }
    }
}

/// One CPU-executable model: the layer IR plus the dataset geometry the
/// synthetic pipeline needs. [`crate::runtime::ReferenceBackend`]'s
/// in-memory manifest is generated from [`cpu_ladder`].
#[derive(Debug, Clone)]
pub struct CpuModel {
    /// Manifest name (`--model` key).
    pub name: &'static str,
    /// Architecture family label for the manifest.
    pub family: &'static str,
    /// Square input image side.
    pub image: usize,
    /// Input channels.
    pub channels: usize,
    /// Classes (== `d_out` of the last layer).
    pub num_classes: usize,
    /// Clipping norm C baked into the lowered accum graphs.
    pub clip_norm: f64,
    /// The executable layer chain.
    pub layers: Vec<LayerSpec>,
}

impl CpuModel {
    /// Total flat parameters over all layers.
    pub fn params(&self) -> usize {
        self.layers.iter().map(LayerSpec::params).sum()
    }

    /// Forward FLOPs per example (2 * MACs over the layer chain).
    pub fn fwd_flops_per_example(&self) -> f64 {
        self.layers.iter().map(|l| 2.0 * l.macs() as f64).sum()
    }
}

/// The CPU-executable model ladder: every model the reference backend
/// lowers in its in-memory manifest. `ref-linear` is the seed's
/// single-layer model (its one-dense-layer IR reproduces the original
/// hardcoded linear+softmax kernel bitwise — pinned by the oracle
/// proptest in `rust/tests/layered_models.rs`); `mlp-small` is the
/// first genuinely deep rung (two ReLU hidden layers), where ghost
/// clipping and the mixed decision rule become observable; `cnn-small`
/// (two convs: stride 1 and stride 2, both padded) and `attn-tiny`
/// (attention + layernorm) execute the paper's real layer kinds, where
/// the mix rule makes its first genuinely split decision (the padded
/// convs' im2col views are per-example territory, the dense head is
/// ghost — DESIGN.md §13).
pub fn cpu_ladder() -> Vec<CpuModel> {
    let d = 16 * 16 * 3;
    vec![
        CpuModel {
            name: "ref-linear",
            family: "linear",
            image: 16,
            channels: 3,
            num_classes: 10,
            clip_norm: 1.0,
            layers: vec![LayerSpec::dense(d, 10)],
        },
        CpuModel {
            name: "mlp-small",
            family: "mlp",
            image: 16,
            channels: 3,
            num_classes: 10,
            clip_norm: 1.0,
            layers: vec![
                LayerSpec::dense_relu(d, 64),
                LayerSpec::dense_relu(64, 32),
                LayerSpec::dense(32, 10),
            ],
        },
        CpuModel {
            name: "mlp-wide",
            family: "mlp",
            image: 16,
            channels: 3,
            num_classes: 10,
            clip_norm: 1.0,
            layers: vec![LayerSpec::dense_relu(d, 128), LayerSpec::dense(128, 10)],
        },
        CpuModel {
            name: "cnn-small",
            family: "cnn",
            image: 8,
            channels: 3,
            num_classes: 10,
            clip_norm: 1.0,
            layers: vec![
                // [3, 8, 8] -> [4, 8, 8] (k3 s1 p1) -> [6, 4, 4] (k3 s2 p1)
                LayerSpec::conv2d(3, 8, 4, 3, 1, 1, Activation::Relu),
                LayerSpec::conv2d(4, 8, 6, 3, 2, 1, Activation::Relu),
                LayerSpec::dense(6 * 4 * 4, 10),
            ],
        },
        CpuModel {
            name: "attn-tiny",
            family: "attn",
            image: 4,
            channels: 3,
            num_classes: 10,
            clip_norm: 1.0,
            layers: vec![
                // 48 inputs viewed as 4 tokens x 12 features.
                LayerSpec::attention(4, 12, 6),
                LayerSpec::layernorm(48),
                LayerSpec::dense(48, 10),
            ],
        },
    ]
}

/// One linear (or linear-equivalent) layer, as seen by ghost clipping:
/// an effective sequence length `t` (tokens for ViT, spatial positions
/// for a conv's im2col view) and the weight dims.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearDims {
    pub t: usize,
    pub d_in: usize,
    pub d_out: usize,
}

impl LinearDims {
    pub fn weight_params(&self) -> usize {
        self.d_in * self.d_out + self.d_out
    }
}

/// Architecture family, mirroring the paper's two model families.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    ViT,
    BiTResNet,
}

/// A paper-scale architecture description, sufficient for the analytic
/// memory / cost / decision models.
#[derive(Debug, Clone)]
pub struct Arch {
    pub name: String,
    pub family: Family,
    /// All ghost-relevant linear layers (ViT: every dense; ResNet: convs
    /// in their im2col view + head).
    pub linears: Vec<LinearDims>,
    /// Parameters not in `linears` (LayerNorm/GroupNorm scales, cls,
    /// positional embeddings, ...).
    pub other_params: usize,
    /// Stored-activation floats per example (forward tape for backward).
    pub act_floats_per_example: usize,
    /// Forward FLOPs per example (2*MACs).
    pub fwd_flops_per_example: f64,
    /// Sequence length (ViT) — 0 for ResNets.
    pub tokens: usize,
}

impl Arch {
    /// Total trainable parameters.
    pub fn params(&self) -> usize {
        self.linears.iter().map(|l| l.weight_params()).sum::<usize>() + self.other_params
    }

    /// Millions of parameters, for Table-1-style reporting.
    pub fn params_m(&self) -> f64 {
        self.params() as f64 / 1e6
    }
}

/// Standard ViT at 224x224, patch 16 (the paper's Table 1 ladder).
pub fn vit(name: &str, depth: usize, dim: usize, mlp_ratio: usize) -> Arch {
    let image = 224;
    let patch = 16;
    let t = (image / patch) * (image / patch) + 1; // 197 incl. cls
    let num_classes = 100;
    let patch_dim = patch * patch * 3;
    let m = mlp_ratio * dim;

    let mut linears = vec![LinearDims { t: t - 1, d_in: patch_dim, d_out: dim }];
    for _ in 0..depth {
        linears.push(LinearDims { t, d_in: dim, d_out: 3 * dim }); // qkv
        linears.push(LinearDims { t, d_in: dim, d_out: dim }); // proj
        linears.push(LinearDims { t, d_in: dim, d_out: m }); // fc1
        linears.push(LinearDims { t, d_in: m, d_out: dim }); // fc2
    }
    linears.push(LinearDims { t: 1, d_in: dim, d_out: num_classes }); // head

    // LayerNorms (2 per block + final), cls token, positional embedding.
    let other = depth * 2 * 2 * dim + 2 * dim + dim + t * dim;

    // Forward tape per example: inputs of each linear + attention
    // matrices + softmax + residual streams. Coefficient choices follow
    // the standard ViT memory breakdown; `12` covers the per-block
    // re-materialized tensors (x, ln1, qkv(3), attn-out, proj-in, ln2,
    // fc1-out(4 as gelu in+out)), heads*T^2 the attention maps.
    let heads = dim / 64;
    let act = depth * (12 * t * dim + 2 * heads * t * t) + 4 * t * dim;

    let mut flops = 0.0;
    for l in &linears {
        flops += 2.0 * l.t as f64 * l.d_in as f64 * l.d_out as f64;
    }
    flops += depth as f64 * 2.0 * 2.0 * (t * t * dim) as f64; // QK^T + AV

    Arch {
        name: name.to_string(),
        family: Family::ViT,
        linears,
        other_params: other,
        act_floats_per_example: act,
        fwd_flops_per_example: flops,
        tokens: t,
    }
}

/// BiT-ResNet at 224x224: `depths` bottlenecks per stage, width factor
/// `wf` (the paper's x1/x3/x4).
pub fn bit_resnet(name: &str, depths: &[usize], wf: usize) -> Arch {
    let num_classes = 100;
    let mut linears = Vec::new();
    let mut other = 0usize;
    let mut act = 0usize;
    let mut flops = 0.0f64;

    // Root: 7x7/2 conv then 3x3/2 maxpool => 56x56 into stage 1.
    let root_c = 64 * wf;
    let mut h = 112usize;
    linears.push(LinearDims { t: h * h, d_in: 7 * 7 * 3, d_out: root_c });
    act += h * h * root_c;
    flops += 2.0 * (h * h) as f64 * (7 * 7 * 3 * root_c) as f64;
    h = 56;

    let mut cin = root_c;
    for (s, &d) in depths.iter().enumerate() {
        let cout = 256 * (1 << s) * wf;
        let mid = cout / 4;
        if s > 0 {
            h /= 2;
        }
        for b in 0..d {
            let t = h * h;
            // 1x1 reduce, 3x3, 1x1 expand (+ projection on first block)
            linears.push(LinearDims { t, d_in: cin, d_out: mid });
            linears.push(LinearDims { t, d_in: 9 * mid, d_out: mid });
            linears.push(LinearDims { t, d_in: mid, d_out: cout });
            if b == 0 {
                linears.push(LinearDims { t, d_in: cin, d_out: cout });
            }
            // GroupNorm params (3 per block), stored activations ~ the
            // three conv inputs + outputs.
            other += 2 * (cin + 2 * mid);
            act += t * (cin + 4 * mid + cout);
            flops += 2.0 * t as f64 * (cin * mid + 9 * mid * mid + mid * cout) as f64;
            cin = cout;
        }
    }
    other += 2 * cin;
    linears.push(LinearDims { t: 1, d_in: cin, d_out: num_classes });
    flops += 2.0 * (cin * num_classes) as f64;

    // Conv weights counted via im2col dims double-count biases (convs in
    // BiT have no biases); compensate by subtracting the d_out "bias"
    // terms we added in weight_params for all but the head.
    let bias_overcount: usize = linears[..linears.len() - 1].iter().map(|l| l.d_out).sum();
    other = other.saturating_sub(bias_overcount.min(other));

    Arch {
        name: name.to_string(),
        family: Family::BiTResNet,
        linears,
        other_params: other,
        act_floats_per_example: act,
        fwd_flops_per_example: flops,
        tokens: 0,
    }
}

/// The paper's Table 1 ladder, full scale.
pub fn paper_ladder() -> Vec<Arch> {
    vec![
        vit("ViT-Tiny", 12, 192, 4),
        vit("ViT-Small", 12, 384, 4),
        vit("ViT-Base", 12, 768, 4),
        vit("ViT-Large", 24, 1024, 4),
        vit("ViT-Huge", 32, 1280, 4),
        bit_resnet("BiT-R50x1", &[3, 4, 6, 3], 1),
        bit_resnet("BiT-R101x1", &[3, 4, 23, 3], 1),
        bit_resnet("BiT-R50x3", &[3, 4, 6, 3], 3),
        bit_resnet("BiT-R101x3", &[3, 4, 23, 3], 3),
        bit_resnet("BiT-R152x4", &[3, 8, 36, 3], 4),
    ]
}

/// Table 1 reference values (millions of parameters) for validation.
pub const TABLE1_PARAMS_M: &[(&str, f64)] = &[
    ("ViT-Tiny", 5.7),
    ("ViT-Small", 22.1),
    ("ViT-Base", 86.6),
    ("ViT-Large", 304.3),
    ("ViT-Huge", 630.8),
    ("BiT-R50x1", 23.7),
    ("BiT-R101x1", 42.7),
    ("BiT-R50x3", 211.8),
    ("BiT-R101x3", 382.4),
    ("BiT-R152x4", 929.2),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_param_counts_match_paper() {
        // Heads differ (1000-class ImageNet vs our 100) and BiT counts
        // include minor extras, so allow 8% — the ladder *shape* is what
        // the analytic studies depend on.
        let ladder = paper_ladder();
        for (name, want_m) in TABLE1_PARAMS_M {
            let arch = ladder.iter().find(|a| a.name == *name).unwrap();
            let got = arch.params_m();
            let rel = (got - want_m).abs() / want_m;
            assert!(rel < 0.08, "{name}: got {got:.1}M want {want_m}M");
        }
    }

    #[test]
    fn vit_monotone_ladder() {
        let l = paper_ladder();
        for w in l[..5].windows(2) {
            assert!(w[1].params() > w[0].params());
            assert!(w[1].fwd_flops_per_example > w[0].fwd_flops_per_example);
        }
    }

    #[test]
    fn resnet_width_dominates_depth() {
        // Paper Section 4.1: width affects cost much more than depth.
        let r101x1 = bit_resnet("r101x1", &[3, 4, 23, 3], 1);
        let r50x3 = bit_resnet("r50x3", &[3, 4, 6, 3], 3);
        assert!(r50x3.params() > 3 * r101x1.params());
    }

    #[test]
    fn cpu_ladder_is_well_formed() {
        let ladder = cpu_ladder();
        for name in ["ref-linear", "mlp-small", "cnn-small", "attn-tiny"] {
            assert!(ladder.iter().any(|m| m.name == name), "{name} missing");
        }
        for m in &ladder {
            let d = m.image * m.image * m.channels;
            assert_eq!(m.layers.first().unwrap().d_in, d, "{}", m.name);
            assert_eq!(m.layers.last().unwrap().d_out, m.num_classes, "{}", m.name);
            assert_eq!(m.layers.last().unwrap().activation, Activation::None, "{}", m.name);
            assert_eq!(m.layers.last().unwrap().kind, LayerKind::Dense, "{}", m.name);
            for w in m.layers.windows(2) {
                assert_eq!(w[0].d_out, w[1].d_in, "{}: layer chain broken", m.name);
            }
            assert_eq!(m.params(), m.layers.iter().map(LayerSpec::params).sum::<usize>());
            assert!(m.fwd_flops_per_example() > 0.0);
        }
        // The seed model keeps its exact shape (and therefore its exact
        // flat layout [W | b]).
        let lin = ladder.iter().find(|m| m.name == "ref-linear").unwrap();
        assert_eq!(lin.layers.len(), 1);
        assert_eq!(lin.params(), 10 * 16 * 16 * 3 + 10);
        // mlp-small is genuinely deep: two hidden ReLU layers + head.
        let mlp = ladder.iter().find(|m| m.name == "mlp-small").unwrap();
        assert_eq!(mlp.layers.len(), 3);
        assert!(mlp.layers[..2].iter().all(|l| l.activation == Activation::Relu));
        // cnn-small exercises both stride 1 and stride 2, both padded.
        let cnn = ladder.iter().find(|m| m.name == "cnn-small").unwrap();
        let strides: Vec<usize> = cnn
            .layers
            .iter()
            .filter_map(|l| match l.kind {
                LayerKind::Conv2d { stride, pad, .. } => {
                    assert!(pad > 0);
                    Some(stride)
                }
                _ => None,
            })
            .collect();
        assert_eq!(strides, vec![1, 2]);
        // K: 4*3*3*3 + 4 = 112, 6*4*3*3 + 6 = 222, dense 96*10 + 10.
        assert_eq!(cnn.params(), 112 + 222 + 970);
        // attn-tiny factors its 48-wide input as 4 tokens x 12 features.
        let attn = ladder.iter().find(|m| m.name == "attn-tiny").unwrap();
        assert_eq!(attn.layers[0].kind, LayerKind::Attention { t: 4, d_model: 12, d_head: 6 });
        assert_eq!(attn.layers[1].kind, LayerKind::LayerNorm);
        // 3*(12*6+6) + 12*6+12 = 318, layernorm 96, dense 48*10+10.
        assert_eq!(attn.params(), 318 + 96 + 490);
    }

    #[test]
    fn layer_kind_params_and_macs_match_hand_counts() {
        // conv2d: [3, 8, 8] -(k3 s2 p1)-> [4, 4, 4]: T = 16 positions,
        // patch = 27, so 16*27*4 MACs; params 4*27 + 4.
        let c = LayerSpec::conv2d(3, 8, 4, 3, 2, 1, Activation::Relu);
        assert_eq!((c.d_in, c.d_out), (192, 64));
        assert_eq!(c.params(), 112);
        assert_eq!(c.macs(), 16 * 27 * 4);
        assert_eq!(c.linear_dims(), LinearDims { t: 16, d_in: 27, d_out: 4 });
        // floor semantics: 7x7, k3 s2 p0 -> 3x3.
        assert_eq!(conv_out(7, 3, 2, 0), 3);
        // layernorm: gamma + beta.
        let ln = LayerSpec::layernorm(48);
        assert_eq!((ln.d_in, ln.d_out, ln.params()), (48, 48, 96));
        // attention: qkv (3x [6,12]+6) + proj ([12,6]+12) over t=4.
        let at = LayerSpec::attention(4, 12, 6);
        assert_eq!((at.d_in, at.d_out), (48, 48));
        assert_eq!(at.params(), 3 * (72 + 6) + 72 + 12);
        // 4 projections t*d*dh + QK^T and AV at t^2*dh each.
        assert_eq!(at.macs(), 4 * 4 * 12 * 6 + 2 * 16 * 6);
        assert_eq!(at.linear_dims(), LinearDims { t: 4, d_in: 12, d_out: 18 });
    }

    #[test]
    fn activation_roundtrips_through_manifest_strings() {
        for a in [Activation::None, Activation::Relu] {
            assert_eq!(Activation::parse(a.as_str()), Some(a));
        }
        assert_eq!(Activation::parse("gelu"), None);
    }
}
