//! Synthetic CIFAR-100-like dataset (seeded, class-conditional).
//!
//! The paper benchmarks on CIFAR-100 resized to 224x224; throughput
//! benchmarking never inspects label quality, and the e2e training run
//! only needs a *learnable* signal. We substitute a deterministic
//! class-conditional Gaussian dataset: each class has a fixed smooth
//! pattern (drawn once from a per-class ChaCha stream), and each example
//! is its class pattern plus per-example noise. Images regenerate on
//! demand from the index — no storage, any dataset size, perfectly
//! reproducible across runs and languages.

use crate::util::rng::ChaChaRng;

/// Deterministic synthetic image-classification dataset.
#[derive(Debug, Clone)]
pub struct SyntheticDataset {
    n: u32,
    classes: u32,
    image: usize,
    channels: usize,
    noise: f32,
    seed: u64,
    /// Per-class base patterns, generated once: [classes, image*image*ch].
    patterns: Vec<Vec<f32>>,
}

impl SyntheticDataset {
    pub fn new(n: u32, classes: u32, image: usize, channels: usize, seed: u64) -> Self {
        assert!(classes >= 2);
        let dim = image * image * channels;
        let mut patterns = Vec::with_capacity(classes as usize);
        for c in 0..classes {
            let mut rng = ChaChaRng::from_seed_stream(seed, c as u64, b"classpat");
            // Smooth-ish pattern: low-frequency sinusoid mixture.
            let (fx, fy, phase): (f64, f64, f64) = (
                0.5 + 2.5 * rng.next_f64(),
                0.5 + 2.5 * rng.next_f64(),
                std::f64::consts::TAU * rng.next_f64(),
            );
            let amp: f32 = 1.0;
            let mut pat = vec![0.0f32; dim];
            for y in 0..image {
                for x in 0..image {
                    for ch in 0..channels {
                        let v = ((x as f64 / image as f64) * fx * std::f64::consts::TAU
                            + (y as f64 / image as f64) * fy * std::f64::consts::TAU
                            + phase
                            + ch as f64)
                            .sin();
                        pat[(y * image + x) * channels + ch] = amp * v as f32;
                    }
                }
            }
            patterns.push(pat);
        }
        Self { n, classes, image, channels, noise: 0.5, seed, patterns }
    }

    /// CIFAR-100-shaped default: 32x32x3, 100 classes.
    pub fn cifar_like(n: u32, seed: u64) -> Self {
        Self::new(n, 100, 32, 3, seed)
    }

    pub fn len(&self) -> u32 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn image_dim(&self) -> usize {
        self.image * self.image * self.channels
    }

    /// Label of example `idx` (deterministic hash of the index).
    pub fn label(&self, idx: u32) -> i32 {
        // splitmix-style mix so labels are balanced but not periodic
        let mut z = (idx as u64).wrapping_add(self.seed).wrapping_mul(0x9E3779B97F4A7C15);
        z ^= z >> 30;
        z = z.wrapping_mul(0xBF58476D1CE4E5B9);
        z ^= z >> 27;
        (z % self.classes as u64) as i32
    }

    /// Materialize example `idx` into `out` (len = image_dim).
    pub fn fill_example(&self, idx: u32, out: &mut [f32]) {
        let class = self.label(idx) as usize;
        let mut rng = ChaChaRng::from_seed_stream(self.seed, idx as u64, b"example\0");
        let pat = &self.patterns[class];
        for (o, &p) in out.iter_mut().zip(pat) {
            let eps = rng.next_normal() as f32;
            *o = p + self.noise * eps;
        }
    }

    /// Gather a batch: images `[b, image, image, channels]` row-major
    /// and labels `[b]`. `indices` may repeat (Algorithm-2 padding does).
    pub fn batch(&self, indices: &[u32]) -> (Vec<f32>, Vec<i32>) {
        let d = self.image_dim();
        let mut xs = vec![0.0f32; indices.len() * d];
        let mut ys = Vec::with_capacity(indices.len());
        for (i, &idx) in indices.iter().enumerate() {
            self.fill_example(idx, &mut xs[i * d..(i + 1) * d]);
            ys.push(self.label(idx));
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let d1 = SyntheticDataset::cifar_like(1000, 7);
        let d2 = SyntheticDataset::cifar_like(1000, 7);
        let (x1, y1) = d1.batch(&[0, 5, 999]);
        let (x2, y2) = d2.batch(&[0, 5, 999]);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn different_seed_different_data() {
        let d1 = SyntheticDataset::cifar_like(100, 1);
        let d2 = SyntheticDataset::cifar_like(100, 2);
        assert_ne!(d1.batch(&[3]).0, d2.batch(&[3]).0);
    }

    #[test]
    fn labels_roughly_balanced() {
        let d = SyntheticDataset::cifar_like(50_000, 3);
        let mut counts = vec![0u32; 100];
        for i in 0..50_000 {
            counts[d.label(i) as usize] += 1;
        }
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(*min > 350 && *max < 650, "min={min} max={max}");
    }

    #[test]
    fn class_signal_exceeds_noise() {
        // Same-class examples must be closer than different-class ones.
        let d = SyntheticDataset::new(1000, 10, 16, 3, 5);
        let mut by_class: Vec<Vec<u32>> = vec![vec![]; 10];
        for i in 0..1000 {
            by_class[d.label(i) as usize].push(i);
        }
        let dist = |a: u32, b: u32| {
            let (xa, _) = d.batch(&[a]);
            let (xb, _) = d.batch(&[b]);
            xa.iter().zip(&xb).map(|(p, q)| (p - q).powi(2)).sum::<f32>()
        };
        let same = dist(by_class[0][0], by_class[0][1]);
        let diff = dist(by_class[0][0], by_class[1][0]);
        assert!(diff > 1.5 * same, "same={same} diff={diff}");
    }

    #[test]
    fn batch_shapes() {
        let d = SyntheticDataset::cifar_like(10, 0);
        let (x, y) = d.batch(&[1, 1, 2]);
        assert_eq!(x.len(), 3 * 32 * 32 * 3);
        assert_eq!(y.len(), 3);
        assert_eq!(y[0], y[1]);
    }
}
