//! Analytic accelerator-memory model: what determines the paper's
//! **maximum physical batch size** (Figure 3, Table 3).
//!
//! The paper measures the largest physical batch before CUDA OOM on
//! 32 GB V100 / 40 GB A100. Our substrate has no VRAM, so we model the
//! footprint: each clipping method differs *structurally* in what it
//! must hold per example —
//!
//! * non-private:     forward tape (activations) only
//! * per-example:     tape (held longer by the hooks) **+ the [B, P]
//!                    per-example gradient tensor** — the O(B*P) term
//!                    that collapses the max batch (x4..x11 in Fig. 3)
//! * ghost (PV):      tape + tiny T^2 Gram buffers (norms); no [B, P]
//! * book keeping:    tape + the cached per-layer output-grads b_l
//!                    needed to rebuild clipped sums (the "small memory
//!                    cost" vs ghost the paper notes)
//! * masked JAX:      [B, P] like per-example but without hook overhead
//!
//! Coefficients are calibrated once against Table 3 (ViT-Base, A100
//! 40 GB) and then *validated* against the V100 column and the Figure 3
//! model ladder in tests — i.e. one column fits, the rest must follow.

use crate::clipping::ClippingMethod;
use crate::models::Arch;

/// Calibrated footprint coefficients (dimensionless multipliers on the
/// stored-activation bytes, see module docs).
#[derive(Debug, Clone, Copy)]
pub struct MemModel {
    /// Non-private: tape + transient backward buffers.
    pub k_act_nonprivate: f64,
    /// Ghost clipping: tape held through the second backward + Grams.
    pub k_act_ghost: f64,
    /// Per-example (Opacus): hooks keep activations + per-layer backprops.
    pub k_act_perexample: f64,
    /// Per-example grad_sample storage multiplier (fp32 + einsum buffer).
    pub k_grad_perexample: f64,
    /// Masked JAX: vmapped tape; per-example grads materialized once.
    pub k_act_masked: f64,
    pub k_grad_masked: f64,
    /// Fixed runtime overhead (context, workspace), bytes.
    pub fixed_overhead: f64,
}

impl Default for MemModel {
    fn default() -> Self {
        Self {
            k_act_nonprivate: 1.05,
            k_act_ghost: 1.10,
            k_act_perexample: 3.0,
            k_grad_perexample: 2.0,
            k_act_masked: 1.6,
            k_grad_masked: 1.0,
            fixed_overhead: 1.5e9,
        }
    }
}

impl MemModel {
    /// Static (batch-independent) bytes: weights + summed grads + a
    /// working copy (optimizer/update), plus the reference kernels'
    /// cache-block buffers.
    fn static_bytes(&self, arch: &Arch) -> f64 {
        12.0 * arch.params() as f64 + Self::block_buffer_bytes(arch) + self.fixed_overhead
    }

    /// Reference-kernel cache-block buffers (DESIGN.md §14): the
    /// blocked GEMM keeps two f32 panel buffers per worker, sized by
    /// the widest per-row unit (`d_in + 1`, the bias column included).
    /// Priced at the worker-pool cap (8, the reference backend's
    /// auto-thread ceiling) because the scratch pool is allocated up
    /// front; batch-independent, so it lands in the static term.
    fn block_buffer_bytes(arch: &Arch) -> f64 {
        let widest = arch.linears.iter().map(|l| l.d_in + 1).max().unwrap_or(0);
        2.0 * 4.0 * widest as f64 * 8.0
    }

    /// Book-Keeping per-example extra: cached output-grads sum_l T_l * d_out_l.
    fn bk_extra_floats(arch: &Arch) -> f64 {
        arch.linears
            .iter()
            .map(|l| (l.t * l.d_out) as f64)
            .sum()
    }

    /// Ghost per-example extra: the two T_l x T_l Grams of the largest
    /// layer (computed layer-at-a-time, so only the max is live).
    fn ghost_extra_floats(arch: &Arch) -> f64 {
        arch.linears
            .iter()
            .map(|l| 2.0 * (l.t * l.t) as f64)
            .fold(0.0, f64::max)
    }

    /// Peak bytes at physical batch `b` for `method` on `arch`.
    pub fn peak_bytes(&self, arch: &Arch, method: ClippingMethod, b: usize) -> f64 {
        let act = arch.act_floats_per_example as f64 * 4.0;
        let p4 = arch.params() as f64 * 4.0;
        let bf = b as f64;
        let per_example = match method {
            ClippingMethod::NonPrivate => act * self.k_act_nonprivate,
            ClippingMethod::PerExample => {
                act * self.k_act_perexample + p4 * self.k_grad_perexample
            }
            ClippingMethod::Ghost | ClippingMethod::MixGhost => {
                act * self.k_act_ghost + Self::ghost_extra_floats(arch) * 4.0
            }
            ClippingMethod::BkGhost
            | ClippingMethod::BkMixGhost
            | ClippingMethod::BkMixOpt => {
                act * self.k_act_nonprivate + Self::bk_extra_floats(arch) * 4.0
            }
            ClippingMethod::MaskedJax | ClippingMethod::NaiveJax => {
                act * self.k_act_masked + p4 * self.k_grad_masked
            }
        };
        self.static_bytes(arch) + bf * per_example
    }

    /// Largest physical batch fitting in `budget_bytes` (0 if even b=1
    /// does not fit — the "too large to fit one example" regime the
    /// paper flags for Huge models under per-example clipping).
    pub fn max_physical_batch(
        &self,
        arch: &Arch,
        method: ClippingMethod,
        budget_bytes: f64,
    ) -> usize {
        if self.peak_bytes(arch, method, 1) > budget_bytes {
            return 0;
        }
        let (mut lo, mut hi) = (1usize, 2usize);
        while self.peak_bytes(arch, method, hi) <= budget_bytes {
            lo = hi;
            hi *= 2;
            if hi > 1 << 24 {
                break;
            }
        }
        while lo + 1 < hi {
            let mid = lo + (hi - lo) / 2;
            if self.peak_bytes(arch, method, mid) <= budget_bytes {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }
}

/// GPU memory budgets used throughout the paper.
pub const A100_BYTES: f64 = 40.0e9;
pub const V100_BYTES: f64 = 32.0e9;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{paper_ladder, vit};

    fn vit_base() -> Arch {
        vit("ViT-Base", 12, 768, 4)
    }

    #[test]
    fn table3_a100_ordering_and_magnitudes() {
        // Paper Table 3 (ViT-Base, A100 40GB): NP 268, PerEx 35,
        // Ghost 257, BK 209. Calibrated model must land within 30% and
        // preserve the strict ordering NP > Ghost > BK >> PerEx.
        let m = MemModel::default();
        let a = vit_base();
        let np = m.max_physical_batch(&a, ClippingMethod::NonPrivate, A100_BYTES);
        let pe = m.max_physical_batch(&a, ClippingMethod::PerExample, A100_BYTES);
        let gh = m.max_physical_batch(&a, ClippingMethod::Ghost, A100_BYTES);
        let bk = m.max_physical_batch(&a, ClippingMethod::BkGhost, A100_BYTES);
        assert!(np > gh && gh > bk && bk > pe, "{np} {gh} {bk} {pe}");
        for (got, want) in [(np, 268.0), (pe, 35.0), (gh, 257.0), (bk, 209.0)] {
            let rel = (got as f64 - want).abs() / want;
            assert!(rel < 0.35, "got {got} want {want}");
        }
    }

    #[test]
    fn table3_v100_follows_from_same_calibration() {
        // V100 column (32 GB): NP 216, PerEx 28, Ghost 203, BK 189.
        let m = MemModel::default();
        let a = vit_base();
        let np = m.max_physical_batch(&a, ClippingMethod::NonPrivate, V100_BYTES);
        let pe = m.max_physical_batch(&a, ClippingMethod::PerExample, V100_BYTES);
        assert!((np as f64 - 216.0).abs() / 216.0 < 0.35, "np={np}");
        assert!((pe as f64 - 28.0).abs() / 28.0 < 0.45, "pe={pe}");
    }

    #[test]
    fn perexample_gap_grows_with_model_size() {
        // Figure 3: relative max-batch gap is ~x4 for Tiny, ~x11 for Huge.
        let m = MemModel::default();
        let ladder = paper_ladder();
        let ratios: Vec<f64> = ladder[..5]
            .iter()
            .map(|a| {
                let np = m.max_physical_batch(a, ClippingMethod::NonPrivate, A100_BYTES);
                let pe = m.max_physical_batch(a, ClippingMethod::PerExample, A100_BYTES);
                np as f64 / pe.max(1) as f64
            })
            .collect();
        assert!(
            ratios.windows(2).all(|w| w[1] >= w[0] * 0.95),
            "gap must grow with size: {ratios:?}"
        );
        assert!(ratios[0] > 2.0 && *ratios.last().unwrap() > 8.0, "{ratios:?}");
    }

    #[test]
    fn peak_is_monotone_in_batch() {
        let m = MemModel::default();
        let a = vit_base();
        for method in ClippingMethod::ALL {
            let mut prev = 0.0;
            for b in [1, 2, 8, 32, 128] {
                let p = m.peak_bytes(&a, *method, b);
                assert!(p > prev);
                prev = p;
            }
        }
    }

    #[test]
    fn block_buffers_are_priced_and_monotone_in_layer_width() {
        // The reference kernels' cache-block buffers are static-term
        // bytes: two f32 panels per worker at the 8-worker pool cap,
        // sized by the widest per-row unit (d_in + 1).
        let m = MemModel::default();
        for a in paper_ladder().iter() {
            let bb = MemModel::block_buffer_bytes(a);
            let widest =
                a.linears.iter().map(|l| l.d_in + 1).max().unwrap_or(0) as f64;
            assert_eq!(bb, 2.0 * 4.0 * widest * 8.0, "{}", a.name);
            assert!(bb > 0.0, "{}: block buffers must be priced", a.name);
            assert!(
                m.peak_bytes(a, ClippingMethod::Ghost, 1) > bb,
                "{}: peak must include the buffers",
                a.name
            );
        }
        // Monotone in the widest layer: a wider model never prices
        // smaller panel buffers, and the term is batch-independent.
        let narrow = vit("narrow", 4, 256, 4);
        let wide = vit("wide", 4, 1024, 4);
        assert!(
            MemModel::block_buffer_bytes(&wide) > MemModel::block_buffer_bytes(&narrow)
        );
        let at_1 = m.peak_bytes(&wide, ClippingMethod::Ghost, 1);
        let at_2 = m.peak_bytes(&wide, ClippingMethod::Ghost, 2);
        let at_3 = m.peak_bytes(&wide, ClippingMethod::Ghost, 3);
        assert!((at_3 - at_2 - (at_2 - at_1)).abs() < 1.0, "static term leaks into batch");
    }

    #[test]
    fn oom_at_one_example_reports_zero() {
        let m = MemModel::default();
        let a = vit("huge", 32, 1280, 4);
        assert_eq!(m.max_physical_batch(&a, ClippingMethod::PerExample, 1e9), 0);
    }

    // The serve scheduler's eviction policy prices resident sessions
    // with `peak_bytes` and sizes admissions with `max_physical_batch`;
    // the three tests below pin the properties it relies on.

    #[test]
    fn perexample_dominates_masked_dominates_ghost() {
        // Per-clip-method footprint ordering at any fixed batch:
        // per-example (hooks + [B,P]) ≥ masked JAX ([B,P], no hooks)
        // ≥ ghost (T^2 Grams only). Eviction order depends on it.
        let m = MemModel::default();
        for a in paper_ladder().iter() {
            for b in [1usize, 4, 16, 64, 256] {
                let pe = m.peak_bytes(a, ClippingMethod::PerExample, b);
                let mk = m.peak_bytes(a, ClippingMethod::MaskedJax, b);
                let gh = m.peak_bytes(a, ClippingMethod::Ghost, b);
                assert!(pe >= mk, "{}: b={b} perex {pe} < masked {mk}", a.name);
                assert!(mk >= gh, "{}: b={b} masked {mk} < ghost {gh}", a.name);
            }
        }
    }

    #[test]
    fn max_physical_batch_is_monotone_in_budget() {
        // A larger budget never shrinks the admissible batch, and the
        // reported batch actually fits while batch+1 does not.
        let m = MemModel::default();
        let a = vit_base();
        for method in ClippingMethod::ALL {
            let mut prev = 0usize;
            for budget in [2.0e9, 8.0e9, V100_BYTES, A100_BYTES, 80.0e9] {
                let b = m.max_physical_batch(&a, *method, budget);
                assert!(b >= prev, "{method:?}: budget up, batch down ({prev} -> {b})");
                if b > 0 {
                    assert!(m.peak_bytes(&a, *method, b) <= budget);
                    assert!(m.peak_bytes(&a, *method, b + 1) > budget);
                }
                prev = b;
            }
        }
    }

    #[test]
    fn max_physical_batch_is_antitone_in_peak() {
        // Methods with strictly larger per-example footprints admit no
        // larger batch under the same budget — the ordering the
        // `perexample_dominates_masked_dominates_ghost` test pins must
        // carry through the batch search.
        let m = MemModel::default();
        let a = vit_base();
        for budget in [8.0e9, V100_BYTES, A100_BYTES] {
            let pe = m.max_physical_batch(&a, ClippingMethod::PerExample, budget);
            let mk = m.max_physical_batch(&a, ClippingMethod::MaskedJax, budget);
            let gh = m.max_physical_batch(&a, ClippingMethod::Ghost, budget);
            assert!(pe <= mk && mk <= gh, "budget {budget}: {pe} {mk} {gh}");
        }
    }
}
