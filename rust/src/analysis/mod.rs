//! Static privacy & determinism auditing (`dpshort audit`,
//! `dpshort lint --source`) — the "verify before you run" layer.
//!
//! The paper's thesis is that DP-SGD implementations silently take
//! shortcuts (wrong subsampling, wrong clipping granularity), and the
//! follow-ups arXiv 2403.17673 / 2411.04205 show those shortcuts cost
//! real epsilon. This module makes the contract *statically checkable*
//! before a step runs:
//!
//! 1. [`plan::RunPlan::lower`] resolves (manifest, config, sigma) into
//!    the same lowered description `TrainSession::new` executes;
//! 2. [`taint::Graph::lower`] builds the step dataflow and
//!    [`taint::propagate`] runs the per-example taint fixpoint;
//! 3. [`rules::audit_plan`] judges the plan against the rule catalog
//!    in [`diag`] (clipping coverage, noise placement/scale, RNG
//!    stream injectivity + exhaustion, sampler↔accountant match,
//!    reduction schedule-invariance, materialization, dtypes);
//! 4. [`source_lint::lint_source`] is the companion source-level pass.
//!
//! `TrainSession::new` runs the plan audit and refuses Deny
//! diagnostics unless `--allow-unsound` is set (which stamps the
//! TrainReport and every checkpoint `unaudited`). DESIGN.md §10
//! documents what each rule proves and does not prove.

pub mod diag;
pub mod plan;
pub mod rules;
pub mod source_lint;
pub mod streams;
pub mod taint;

pub use diag::{
    catalog, rule, AuditReport, Diagnostic, RuleInfo, Severity, AUDIT_SCHEMA_VERSION, RULES,
};
pub use plan::{
    gram_groups, test_plan, variant_claims_no_materialization, BudgetSpec, ClipKind, ClipSpec,
    NoiseSite, NoiseStage, ReductionSpec, RetrySpec, RunPlan, SamplerInfo,
};
pub use rules::{audit_hlo, audit_plan, audit_plan_graph};
pub use source_lint::{
    lint_source, parse_allowlist, AllowEntry, LintFinding, LintReport, LintRule, LINT_RULES,
};
pub use streams::{enumerate as enumerate_streams, find_collisions, StreamUse};
pub use taint::{propagate, Graph, NodeKind, Taint, TaintAnalysis};

use crate::coordinator::config::TrainConfig;
use crate::runtime::ModelMeta;
use anyhow::Result;

/// Lower a configured run into its [`RunPlan`] and audit it — the one
/// call `TrainSession::new` and `dpshort audit` share. `manifest_seed`
/// keys the parameter-init stream; `sigma` is the resolved noise
/// multiplier (see `resolve_sigma`).
pub fn audit_run(
    meta: &ModelMeta,
    manifest_seed: u64,
    config: &TrainConfig,
    sigma: f64,
) -> Result<AuditReport> {
    let plan = RunPlan::lower(meta, manifest_seed, config, sigma)?;
    Ok(audit_plan(&plan))
}
