//! [`RunPlan`]: the audited, fully-lowered description of one training
//! run — everything the static rules need, decoupled from the live
//! runtime objects so adversarial fixtures can mutate it freely.
//!
//! [`RunPlan::lower`] is the canonical constructor: it resolves a
//! `ModelMeta` + `TrainConfig` the same way `TrainSession::new` does
//! (layer plan, executed clipping branches, resolved sigma, sampler,
//! reduction topology, RNG stream enumeration). Every field is public
//! on purpose: the fixture suite builds "what a buggy implementation
//! *would* have lowered" by mutating a clean plan, and the rules must
//! flag exactly those mutations.

use crate::analysis::streams::{self, StreamUse};
use crate::clipping::LayerChoice;
use crate::coordinator::config::TrainConfig;
use crate::coordinator::sampler::SamplerChoice;
use crate::models::LayerKind;
use crate::privacy::AccountantKind;
use crate::runtime::{executed_choices, LayerPlan, ModelMeta};
use anyhow::Result;

/// How the plan clips per-example gradients before aggregation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClipKind {
    /// One factor from the global norm over all layers (the contract).
    Global,
    /// Each layer clipped by its own norm — wrong sensitivity.
    PerLayer,
    /// No clipping (nonprivate baseline, or a dropped-clip bug).
    Unclipped,
}

/// Clipping specification.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClipSpec {
    /// Granularity of the clip.
    pub kind: ClipKind,
    /// Clip norm `C`.
    pub norm: f64,
}

/// Where in the dataflow a noise site injects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NoiseStage {
    /// After the cross-group reduction (the contract).
    PostAggregation,
    /// Into a group partial before reduction (per-rank noise bug).
    PreAggregation,
}

/// One Gaussian noise injection site.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NoiseSite {
    /// Placement relative to aggregation.
    pub stage: NoiseStage,
    /// Noise stddev; must equal `sigma * C`.
    pub scale: f64,
}

/// Sampler facts the accounting rules judge.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplerInfo {
    /// Which scheme the run uses.
    pub choice: SamplerChoice,
    /// The Poisson rate the scheme actually provides (`None` = the
    /// shuffle shortcut; accounting over it is invalid).
    pub poisson_rate: Option<f64>,
    /// Whether each rank draws its own subsample (must be false: one
    /// global draw per step, sharded deterministically).
    pub per_rank: bool,
}

/// Reduction topology facts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReductionSpec {
    /// Fixed binary tree whose shape is a function of group count only.
    pub fixed_tree: bool,
    /// Whether the combine order depends on the worker schedule.
    pub worker_dependent: bool,
}

/// What a failed step's retry replays (DESIGN.md §11). The contract:
/// a retry recomputes the *same* step — same Poisson mask, same noise
/// `(seed, stream)` tuple — so recovery is bitwise-identical and the
/// accounted sampling distribution is untouched. Re-drawing either on
/// retry conditions the published draw on failure events, which breaks
/// both properties (the retry analogue of the shuffle shortcut).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetrySpec {
    /// Whether a retry re-samples the per-step Poisson mask.
    pub resample_on_retry: bool,
    /// Whether a retry advances to a fresh noise stream.
    pub fresh_noise_on_retry: bool,
}

/// A declared `(epsilon, delta)` privacy budget the run promises to
/// stay within. Optional: standalone `dpshort train` runs declare none
/// (the target epsilon is a calibration input, not a cap), while serve
/// tenants always declare one and the auditor refuses admission when
/// the configured steps would overspend it (`budget.overspend`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BudgetSpec {
    /// Maximum epsilon the run may spend.
    pub epsilon: f64,
    /// The delta the budget's epsilon is quoted at.
    pub delta: f64,
}

/// The audited description of one run.
#[derive(Debug, Clone)]
pub struct RunPlan {
    /// Model name.
    pub model: String,
    /// Accum variant name.
    pub variant: String,
    /// Whether the run claims a DP guarantee.
    pub private: bool,
    /// Flat parameter count.
    pub n_params: usize,
    /// Flattened input dim of the first layer.
    pub input_dim: usize,
    /// Dataset size `N`.
    pub dataset_size: u64,
    /// `(d_in, d_out)` per layer, chain order.
    pub layer_dims: Vec<(usize, usize)>,
    /// Layer kind per layer, chain order — the taint lowering emits
    /// one Gram-norm node per *parameter group* of each kind (see
    /// [`gram_groups`]), so the clip-coverage rules can judge e.g. an
    /// attention layer whose norm silently omits one projection.
    pub layer_kinds: Vec<LayerKind>,
    /// Executed clipping branch per layer.
    pub choices: Vec<LayerChoice>,
    /// Clip specification.
    pub clip: ClipSpec,
    /// Gaussian noise sites (exactly one, post-aggregation, in a
    /// canonical private plan; empty when sigma == 0 or nonprivate).
    pub noise: Vec<NoiseSite>,
    /// Sampler facts.
    pub sampler: SamplerInfo,
    /// Accountant the run reports epsilon with.
    pub accountant: AccountantKind,
    /// Reduction topology.
    pub reduction: ReductionSpec,
    /// What a step retry replays.
    pub retry: RetrySpec,
    /// Statically enumerated RNG stream uses.
    pub streams: Vec<StreamUse>,
    /// Data-parallel worker count.
    pub workers: usize,
    /// Optimizer steps.
    pub steps: u64,
    /// Resolved noise multiplier.
    pub sigma: f64,
    /// ChaCha block-counter width in bits of the generator the run
    /// uses (64 since the widening; fixtures set 32 to model the old
    /// wrapping generator).
    pub rng_counter_bits: u32,
    /// Distinct executable dtypes the manifest declares for this model.
    pub dtypes: Vec<String>,
    /// The instruction-set the reference kernels will execute with
    /// ("scalar" | "avx2" | "neon" after auto-detection), as reported
    /// by `runtime::kernels::detected_isa`. Wall-clock only under the
    /// fixed-tree contract, but the audit warns when the ISA is not in
    /// the bitwise-verified set (`kernel.unverified-isa`).
    pub kernel_isa: String,
    /// Declared privacy budget, when the run promises one.
    pub budget: Option<BudgetSpec>,
}

/// Variants whose contract says per-example weight gradients are never
/// materialized (the `[B, P]` footprint ghost/BK exist to avoid; the
/// vmapped fused graphs share the property).
pub fn variant_claims_no_materialization(variant: &str) -> bool {
    matches!(variant, "nonprivate" | "naive" | "masked" | "ghost" | "bk")
}

/// How many parameter groups a layer kind folds into its Gram-norm
/// contribution. Attention carries four independent Gram products —
/// the q/k/v projections against the layer input and the output
/// projection against the context rows (DESIGN.md §13) — and the
/// global norm is only the global norm if *all four* flow into the
/// clip factor. Every other kind contributes a single product
/// (dense/conv weight+bias; layernorm gamma+beta share one).
pub fn gram_groups(kind: LayerKind) -> usize {
    match kind {
        LayerKind::Attention { .. } => 4,
        LayerKind::Dense | LayerKind::Conv2d { .. } | LayerKind::LayerNorm => 1,
    }
}

impl RunPlan {
    /// Lower `(meta, config, sigma)` into the canonical plan — exactly
    /// what the trainer will execute. `manifest_seed` keys the
    /// parameter-init stream.
    pub fn lower(
        meta: &ModelMeta,
        manifest_seed: u64,
        config: &TrainConfig,
        sigma: f64,
    ) -> Result<RunPlan> {
        let lp = LayerPlan::build(meta)?;
        let choices = executed_choices(&config.variant, &lp)?;
        let private = config.is_private();
        let clip = ClipSpec {
            kind: if private { ClipKind::Global } else { ClipKind::Unclipped },
            norm: config.clip_norm,
        };
        let noise = if private && sigma > 0.0 {
            vec![NoiseSite { stage: NoiseStage::PostAggregation, scale: sigma * config.clip_norm }]
        } else {
            Vec::new()
        };
        let sampler = SamplerInfo {
            choice: config.sampler,
            poisson_rate: match config.sampler {
                SamplerChoice::Poisson => Some(config.sampling_rate),
                SamplerChoice::Shuffle => None,
            },
            per_rank: false,
        };
        let streams = streams::enumerate(config, meta, manifest_seed, !noise.is_empty());
        let mut dtypes: Vec<String> = meta
            .executables
            .iter()
            .map(|e| e.dtype_or_f32().to_string())
            .collect();
        dtypes.sort();
        dtypes.dedup();
        Ok(RunPlan {
            model: config.model.clone(),
            variant: config.variant.clone(),
            private,
            n_params: lp.n_params,
            input_dim: lp.input_dim,
            dataset_size: u64::from(config.dataset_size),
            layer_dims: lp.layers.iter().map(|l| (l.spec.d_in, l.spec.d_out)).collect(),
            layer_kinds: lp.layers.iter().map(|l| l.spec.kind).collect(),
            choices,
            clip,
            noise,
            sampler,
            accountant: config.accountant,
            reduction: ReductionSpec { fixed_tree: true, worker_dependent: false },
            // The executor always replays the same draw on retry; the
            // unsound knob below exists so the auditor has something
            // real to deny (mirrors `--sampler shuffle`).
            retry: RetrySpec {
                resample_on_retry: config.retry.fresh_draw_on_retry,
                fresh_noise_on_retry: config.retry.fresh_draw_on_retry,
            },
            streams,
            workers: config.workers.max(1),
            steps: config.steps,
            sigma,
            rng_counter_bits: 64,
            dtypes,
            kernel_isa: crate::runtime::kernels::detected_isa(config.kernel == "scalar").into(),
            budget: config
                .declared_epsilon
                .map(|epsilon| BudgetSpec { epsilon, delta: config.delta }),
        })
    }
}

/// A small clean `k`-layer private plan for tests and adversarial
/// fixtures: masked variant, global clip C = 1, sigma = 1, one
/// post-aggregation noise site, Poisson sampler, RDP accountant. Every
/// fixture in the suite starts from this and mutates one aspect.
pub fn test_plan(k: usize) -> RunPlan {
    let sigma = 1.0;
    let layer_dims: Vec<(usize, usize)> = (0..k).map(|l| (8 - l, 8 - l - 1)).collect();
    RunPlan {
        model: "fixture".into(),
        variant: "masked".into(),
        private: true,
        n_params: layer_dims.iter().map(|(i, o)| i * o + o).sum(),
        input_dim: layer_dims.first().map_or(0, |(i, _)| *i),
        dataset_size: 64,
        layer_dims,
        layer_kinds: vec![LayerKind::Dense; k],
        choices: vec![LayerChoice::Ghost; k],
        clip: ClipSpec { kind: ClipKind::Global, norm: 1.0 },
        noise: vec![NoiseSite { stage: NoiseStage::PostAggregation, scale: sigma }],
        sampler: SamplerInfo {
            choice: SamplerChoice::Poisson,
            poisson_rate: Some(0.25),
            per_rank: false,
        },
        accountant: AccountantKind::Rdp,
        reduction: ReductionSpec { fixed_tree: true, worker_dependent: false },
        retry: RetrySpec { resample_on_retry: false, fresh_noise_on_retry: false },
        streams: Vec::new(),
        workers: 1,
        steps: 4,
        sigma,
        rng_counter_bits: 64,
        dtypes: vec!["f32".into()],
        kernel_isa: "scalar".into(),
        budget: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::LayerSpec;

    fn meta() -> ModelMeta {
        let layers = vec![LayerSpec::dense_relu(12, 5), LayerSpec::dense(5, 3)];
        ModelMeta {
            family: "test".into(),
            n_params: layers.iter().map(LayerSpec::params).sum(),
            image: 2,
            channels: 3,
            num_classes: 3,
            clip_norm: 1.0,
            flops_fwd_per_example: 1.0,
            init_params: "t.bin".into(),
            executables: Vec::new(),
            layers,
        }
    }

    #[test]
    fn lowering_matches_the_trainer_contract() {
        let config = TrainConfig {
            model: "t".into(),
            variant: "masked".into(),
            steps: 3,
            ..Default::default()
        };
        let plan = RunPlan::lower(&meta(), 7, &config, 2.0).unwrap();
        assert!(plan.private);
        assert_eq!(plan.clip.kind, ClipKind::Global);
        assert_eq!(plan.noise.len(), 1);
        assert_eq!(plan.noise[0].stage, NoiseStage::PostAggregation);
        assert!((plan.noise[0].scale - 2.0 * config.clip_norm).abs() < 1e-12);
        assert_eq!(plan.layer_dims, vec![(12, 5), (5, 3)]);
        assert_eq!(plan.layer_kinds, vec![LayerKind::Dense; 2]);
        assert_eq!(plan.choices, vec![LayerChoice::Ghost; 2]);
        assert_eq!(plan.sampler.poisson_rate, Some(config.sampling_rate));
        assert!(plan.reduction.fixed_tree);
        assert_eq!(plan.rng_counter_bits, 64);
        assert!(!plan.streams.is_empty());
        // The init stream is keyed by the MANIFEST seed, not run seed.
        assert!(plan
            .streams
            .iter()
            .any(|s| s.purpose == "init.params" && s.seed == 7));
    }

    #[test]
    fn retry_spec_lowers_from_the_config_knob() {
        let sound = TrainConfig { model: "t".into(), ..Default::default() };
        let plan = RunPlan::lower(&meta(), 0, &sound, 1.0).unwrap();
        assert!(!plan.retry.resample_on_retry);
        assert!(!plan.retry.fresh_noise_on_retry);

        let mut unsound = sound;
        unsound.retry.fresh_draw_on_retry = true;
        let plan = RunPlan::lower(&meta(), 0, &unsound, 1.0).unwrap();
        assert!(plan.retry.resample_on_retry);
        assert!(plan.retry.fresh_noise_on_retry);
    }

    #[test]
    fn nonprivate_lowers_unclipped_and_noiseless() {
        let config = TrainConfig {
            model: "t".into(),
            variant: "nonprivate".into(),
            ..Default::default()
        };
        let plan = RunPlan::lower(&meta(), 0, &config, 0.0).unwrap();
        assert!(!plan.private);
        assert_eq!(plan.clip.kind, ClipKind::Unclipped);
        assert!(plan.noise.is_empty());
        assert!(!plan.streams.iter().any(|s| s.purpose.starts_with("noise")));
    }

    #[test]
    fn unknown_variant_fails_lowering() {
        let config = TrainConfig {
            model: "t".into(),
            variant: "mystery".into(),
            ..Default::default()
        };
        assert!(RunPlan::lower(&meta(), 0, &config, 1.0).is_err());
    }

    #[test]
    fn non_dense_layers_lower_their_kinds_and_gram_groups() {
        let layers = vec![
            LayerSpec::attention(4, 12, 6),
            LayerSpec::layernorm(48),
            LayerSpec::dense(48, 10),
        ];
        let meta = ModelMeta {
            family: "attn".into(),
            n_params: layers.iter().map(LayerSpec::params).sum(),
            image: 4,
            channels: 3,
            num_classes: 10,
            clip_norm: 1.0,
            flops_fwd_per_example: 1.0,
            init_params: "t.bin".into(),
            executables: Vec::new(),
            layers,
        };
        let config = TrainConfig {
            model: "attn-tiny".into(),
            variant: "ghost".into(),
            ..Default::default()
        };
        let plan = RunPlan::lower(&meta, 0, &config, 1.0).unwrap();
        assert_eq!(
            plan.layer_kinds,
            vec![
                LayerKind::Attention { t: 4, d_model: 12, d_head: 6 },
                LayerKind::LayerNorm,
                LayerKind::Dense,
            ]
        );
        let groups: Vec<usize> = plan.layer_kinds.iter().map(|&k| gram_groups(k)).collect();
        assert_eq!(groups, vec![4, 1, 1]);
        assert!(crate::analysis::rules::audit_plan(&plan).is_clean());
    }

    #[test]
    fn materialization_contract_per_variant() {
        for v in ["nonprivate", "naive", "masked", "ghost", "bk"] {
            assert!(variant_claims_no_materialization(v), "{v}");
        }
        assert!(!variant_claims_no_materialization("perex"));
        assert!(!variant_claims_no_materialization("mix"));
    }
}
