//! `dpshort lint --source`: a small pattern lint over `rust/src`
//! enforcing the determinism house rules that used to live as ad-hoc
//! greps in CI.
//!
//! Rules (each a substring scan, deliberately dumb and fast):
//!
//! * `lint.hash-iteration` — `HashMap`/`HashSet` in kernel/reduce
//!   paths: hash iteration order is unspecified, so any fold over it
//!   breaks bitwise determinism. Elsewhere (caches keyed for lookup
//!   only) they are fine.
//! * `lint.nondet-rng` — RNG construction that is not a seeded ChaCha
//!   stream (thread/entropy-seeded generators, the `rand` crate,
//!   OS randomness, hash-randomized state) anywhere outside
//!   `util/rng.rs`.
//! * `lint.float-accum` — unordered float accumulation (turbofish f32
//!   sums, f32 folds) in kernel/reduce paths; sums there must go
//!   through the fixed-order helpers.
//! * `lint.clippy-allow` — new clippy attribute escape hatches
//!   anywhere (replaces the old CI grep for `too_many_arguments`).
//! * `lint.unsafe-code` — compiler-unchecked blocks and `core::arch`
//!   intrinsics anywhere outside `runtime/kernels/`, the one sanctioned
//!   home whose SIMD paths the bitwise battery pins against scalar.
//!
//! False positives are suppressed either by an inline `lint:allow`
//! marker on the offending line or by an entry in the checked-in
//! allowlist (`lint-allowlist.txt`): `rule path-substring line-needle`,
//! `#` comments allowed. The pattern literals below are built with
//! `concat!` so this file does not flag itself.

use anyhow::{Context, Result};
use std::fs;
use std::path::{Path, PathBuf};

/// Where a lint rule applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scope {
    /// Only the kernel/reduce files in [`KERNEL_PATHS`].
    KernelPaths,
    /// Every `.rs` file under the scanned root.
    Everywhere,
    /// Every file except those whose path contains the given fragment.
    EverywhereExcept(&'static str),
}

/// One lint rule: an id, the substrings that trigger it, and a scope.
#[derive(Debug, Clone, Copy)]
pub struct LintRule {
    /// Stable rule id (`lint.*` namespace).
    pub id: &'static str,
    /// Substrings that trigger the rule.
    pub patterns: &'static [&'static str],
    /// Which files the rule scans.
    pub scope: Scope,
    /// Why the pattern is forbidden.
    pub why: &'static str,
}

/// Path fragments of the kernel/reduce hot paths (bitwise-determinism
/// critical): the reference kernels, the layer executor, and the
/// multi-session reduction.
pub const KERNEL_PATHS: &[&str] = &[
    "runtime/reference.rs",
    "runtime/layers.rs",
    "runtime/kernels",
    "cluster/parallel.rs",
];

// Pattern literals are split with concat! so the lint never matches its
// own source.
const P_HASHMAP: &str = concat!("Hash", "Map");
const P_HASHSET: &str = concat!("Hash", "Set");
const P_THREAD_RNG: &str = concat!("thread", "_rng");
const P_FROM_ENTROPY: &str = concat!("from_", "entropy");
const P_RAND_CRATE: &str = concat!("rand", "::");
const P_GETRANDOM: &str = concat!("get", "random");
const P_RANDOM_STATE: &str = concat!("Random", "State");
const P_SUM_F32: &str = concat!("sum::<", "f32>()");
const P_FOLD_F32: &str = concat!("fold(0.0", "f32");
const P_CLIPPY_ALLOW: &str = concat!("#[allow(", "clippy::");
const P_UNSAFE: &str = concat!("uns", "afe ");
const P_UNSAFE_BLOCK: &str = concat!("uns", "afe {");
const P_CORE_ARCH: &str = concat!("core::", "arch");
const ALLOW_MARKER: &str = concat!("lint:", "allow");

/// The shipped lint rules.
pub const LINT_RULES: &[LintRule] = &[
    LintRule {
        id: "lint.hash-iteration",
        patterns: &[P_HASHMAP, P_HASHSET],
        scope: Scope::KernelPaths,
        why: "hash iteration order is unspecified; kernel/reduce paths must use BTree or Vec",
    },
    LintRule {
        id: "lint.nondet-rng",
        patterns: &[P_THREAD_RNG, P_FROM_ENTROPY, P_RAND_CRATE, P_GETRANDOM, P_RANDOM_STATE],
        scope: Scope::EverywhereExcept("util/rng.rs"),
        why: "all randomness must come from the seeded ChaCha streams in util/rng.rs",
    },
    LintRule {
        id: "lint.float-accum",
        patterns: &[P_SUM_F32, P_FOLD_F32],
        scope: Scope::KernelPaths,
        why: "float accumulation in kernel paths must use the fixed-order helpers",
    },
    LintRule {
        id: "lint.clippy-allow",
        patterns: &[P_CLIPPY_ALLOW],
        scope: Scope::Everywhere,
        why: "clippy escape hatches are banned; fix the lint or add a justified allowlist entry",
    },
    LintRule {
        id: "lint.unsafe-code",
        patterns: &[P_UNSAFE, P_UNSAFE_BLOCK, P_CORE_ARCH],
        scope: Scope::EverywhereExcept("runtime/kernels"),
        why: "compiler-unchecked code and arch intrinsics live only in runtime/kernels, \
              where the bitwise battery pins every SIMD path against scalar",
    },
];

/// One allowlist entry: `rule path-substring line-needle` (the needle
/// may be empty, matching any line in the file).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AllowEntry {
    /// Rule id the entry applies to.
    pub rule: String,
    /// Path substring the entry applies to.
    pub path: String,
    /// Substring the offending line must contain ("" = any line).
    pub needle: String,
}

/// Parse `lint-allowlist.txt` text (whitespace-separated triples, `#`
/// comments and blank lines skipped).
pub fn parse_allowlist(text: &str) -> Vec<AllowEntry> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .filter_map(|l| {
            let mut parts = l.splitn(3, ' ');
            let rule = parts.next()?.to_string();
            let path = parts.next()?.to_string();
            let needle = parts.next().unwrap_or("").to_string();
            Some(AllowEntry { rule, path, needle })
        })
        .collect()
}

/// One lint finding.
#[derive(Debug, Clone)]
pub struct LintFinding {
    /// Rule id.
    pub rule: &'static str,
    /// Path relative to the scanned root (normalized to `/`).
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// The offending line's text.
    pub text: String,
    /// The rule's rationale.
    pub why: &'static str,
}

/// The lint pass result.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    /// Findings that survived the allowlist and inline markers.
    pub findings: Vec<LintFinding>,
    /// Count of matches suppressed by allowlist entries.
    pub allowed: usize,
    /// Count of matches suppressed by inline markers.
    pub suppressed: usize,
    /// `.rs` files scanned.
    pub files_scanned: usize,
}

/// Recursively collect `.rs` files under `root`, sorted for
/// deterministic output.
fn rs_files(root: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<PathBuf> = fs::read_dir(&dir)
            .with_context(|| format!("reading {}", dir.display()))?
            .map(|e| e.map(|e| e.path()))
            .collect::<std::io::Result<_>>()?;
        entries.sort();
        for p in entries {
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|x| x == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

fn in_scope(scope: Scope, rel: &str) -> bool {
    match scope {
        Scope::KernelPaths => KERNEL_PATHS.iter().any(|k| rel.contains(k)),
        Scope::Everywhere => true,
        Scope::EverywhereExcept(frag) => !rel.contains(frag),
    }
}

/// Run the lint over every `.rs` file under `root`.
pub fn lint_source(root: &Path, allow: &[AllowEntry]) -> Result<LintReport> {
    let mut report = LintReport::default();
    for file in rs_files(root)? {
        report.files_scanned += 1;
        let rel = file
            .strip_prefix(root)
            .unwrap_or(&file)
            .to_string_lossy()
            .replace('\\', "/");
        let text =
            fs::read_to_string(&file).with_context(|| format!("reading {}", file.display()))?;
        for (idx, line) in text.lines().enumerate() {
            for r in LINT_RULES {
                if !in_scope(r.scope, &rel) || !r.patterns.iter().any(|p| line.contains(p)) {
                    continue;
                }
                if line.contains(ALLOW_MARKER) {
                    report.suppressed += 1;
                } else if allow.iter().any(|a| {
                    a.rule == r.id
                        && rel.contains(&a.path)
                        && (a.needle.is_empty() || line.contains(&a.needle))
                }) {
                    report.allowed += 1;
                } else {
                    report.findings.push(LintFinding {
                        rule: r.id,
                        path: rel.clone(),
                        line: idx + 1,
                        text: line.to_string(),
                        why: r.why,
                    });
                }
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write(dir: &Path, rel: &str, text: &str) {
        let p = dir.join(rel);
        fs::create_dir_all(p.parent().unwrap()).unwrap();
        fs::write(p, text).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("dpshort-lint-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn flags_forbidden_patterns_in_scope_only() {
        let d = tmpdir("scope");
        // Kernel path: hash container + float accumulation are findings.
        write(
            &d,
            "runtime/reference.rs",
            &format!("use std::collections::{P_HASHMAP};\nlet s: f32 = xs.iter().{P_SUM_F32};\n"),
        );
        // Non-kernel path: the same hash use is fine; clippy allow is not.
        write(
            &d,
            "runtime/compile_cache.rs",
            &format!("use std::collections::{P_HASHMAP};\n{P_CLIPPY_ALLOW}foo)]\n"),
        );
        // Nondet RNG is allowed only inside util/rng.rs.
        write(&d, "util/rng.rs", &format!("// mentions {P_THREAD_RNG} freely\n"));
        write(&d, "coordinator/trainer.rs", &format!("let r = {P_THREAD_RNG}();\n"));
        let rep = lint_source(&d, &[]).unwrap();
        let mut got: Vec<(&str, String)> =
            rep.findings.iter().map(|f| (f.rule, f.path.clone())).collect();
        got.sort();
        assert_eq!(
            got,
            vec![
                ("lint.clippy-allow", "runtime/compile_cache.rs".to_string()),
                ("lint.float-accum", "runtime/reference.rs".to_string()),
                ("lint.hash-iteration", "runtime/reference.rs".to_string()),
                ("lint.nondet-rng", "coordinator/trainer.rs".to_string()),
            ]
        );
        assert_eq!(rep.files_scanned, 4);
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn allowlist_and_inline_marker_suppress() {
        let d = tmpdir("allow");
        write(
            &d,
            "runtime/layers.rs",
            &format!(
                "let a: f32 = xs.iter().{P_SUM_F32}; // {ALLOW_MARKER}: test-only\nlet b: f32 = ys.iter().{P_SUM_F32};\n"
            ),
        );
        let allow = parse_allowlist(&format!(
            "# comment line\n\nlint.float-accum runtime/layers.rs ys.iter()\nlint.float-accum other.rs {P_SUM_F32}\n"
        ));
        assert_eq!(allow.len(), 2);
        assert_eq!(allow[0].needle, "ys.iter()");
        let rep = lint_source(&d, &allow).unwrap();
        assert!(rep.findings.is_empty(), "findings: {:?}", rep.findings);
        assert_eq!(rep.suppressed, 1);
        assert_eq!(rep.allowed, 1);
        // Without the allowlist, the unmarked line is a finding.
        let rep2 = lint_source(&d, &[]).unwrap();
        assert_eq!(rep2.findings.len(), 1);
        assert_eq!(rep2.findings[0].line, 2);
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn compiler_unchecked_code_is_confined_to_the_kernels_home() {
        let d = tmpdir("kernels-home");
        // Inside runtime/kernels/: intrinsics are the point; no finding.
        write(
            &d,
            "runtime/kernels/mod.rs",
            &format!("use {P_CORE_ARCH}::x86_64::_mm256_add_ps;\nlet v = {P_UNSAFE_BLOCK} f() }};\n"),
        );
        // Anywhere else: both the block form and the fn form are flagged.
        write(
            &d,
            "runtime/reference.rs",
            &format!("let v = {P_UNSAFE_BLOCK} f() }};\npub {P_UNSAFE}fn g() {{}}\n"),
        );
        let rep = lint_source(&d, &[]).unwrap();
        assert_eq!(rep.findings.len(), 2, "findings: {:?}", rep.findings);
        assert!(rep
            .findings
            .iter()
            .all(|f| f.rule == "lint.unsafe-code" && f.path == "runtime/reference.rs"));
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn needleless_entries_cover_whole_files() {
        let entries = parse_allowlist("lint.hash-iteration runtime/compile_cache.rs");
        assert_eq!(
            entries,
            vec![AllowEntry {
                rule: "lint.hash-iteration".into(),
                path: "runtime/compile_cache.rs".into(),
                needle: String::new(),
            }]
        );
    }
}
