//! Diagnostic types + the rule catalog for the static plan audit.
//!
//! Every finding the auditor can emit is declared here, with a stable
//! rule ID and a fixed severity, so `dpshort audit --json` output is
//! schema-checkable ([`AuditReport::validate`]) and DESIGN.md §10 can
//! document exactly what each rule proves. Severities:
//!
//! * **Deny** — the plan violates the DP or determinism contract;
//!   `TrainSession::new` refuses to run it (opt out: `--allow-unsound`,
//!   which stamps the report and every checkpoint `unaudited`).
//! * **Warn** — the plan is executable but carries no (or a weakened)
//!   guarantee; surfaced, never blocking.
//! * **Info** — advisory only.

use anyhow::{anyhow, Result};
use serde::Serialize;
use std::fmt;

/// Version of the `dpshort audit --json` diagnostic schema.
pub const AUDIT_SCHEMA_VERSION: u32 = 1;

/// Stable rule identifiers. The catalog entry for each is in [`RULES`].
pub mod rule {
    /// Per-example gradient reaches a shared accumulator unclipped.
    pub const CLIP_MISSING: &str = "clip.missing";
    /// Clip factor derives from a strict subset of the layer norms.
    pub const CLIP_PER_LAYER: &str = "clip.per-layer";
    /// The nonprivate baseline aggregates unclipped gradients by design.
    pub const CLIP_NONPRIVATE: &str = "clip.nonprivate";
    /// No Gaussian noise site although sigma > 0 on a private variant.
    pub const NOISE_MISSING: &str = "noise.missing";
    /// More than one Gaussian noise site in the plan.
    pub const NOISE_DOUBLE: &str = "noise.double";
    /// Noise injected before the gradient aggregation completes.
    pub const NOISE_PRE_AGGREGATION: &str = "noise.pre-aggregation";
    /// Noise stddev differs from the calibrated `sigma * C`.
    pub const NOISE_SCALE: &str = "noise.scale";
    /// Private variant with sigma == 0: no guarantee (epsilon infinite).
    pub const NOISE_ZERO_SIGMA: &str = "noise.zero-sigma";
    /// Two RNG stream uses share a `(seed, stream, label)` tuple.
    pub const STREAM_COLLISION: &str = "stream.collision";
    /// A stream's statically-predicted draw exceeds its keystream capacity.
    pub const STREAM_EXHAUSTION: &str = "stream.exhaustion";
    /// Draw exceeds the pre-widening 32-bit-counter capacity (2^38 bytes).
    pub const STREAM_LEGACY_EXHAUSTION: &str = "stream.legacy-exhaustion";
    /// Sampler provides no Poisson rate but the accountant assumes one.
    pub const SHORTCUT_EPSILON: &str = "accountant.shortcut-epsilon";
    /// Plan subsamples per rank instead of one global draw per step.
    pub const SAMPLER_PER_RANK: &str = "sampler.per-rank";
    /// Retry policy re-samples the mask or re-draws noise on step retry.
    pub const RETRY_FRESH_DRAW: &str = "retry.fresh-draw";
    /// Reduction is not the schedule-invariant fixed binary tree.
    pub const REDUCE_SCHEDULE: &str = "reduce.schedule";
    /// A no-materialization variant materializes per-example grads.
    pub const MATERIALIZED_PER_EXAMPLE: &str = "memory.materialized-per-example";
    /// An executable declares a dtype the memory model does not know.
    pub const DTYPE_UNKNOWN: &str = "dtype.unknown";
    /// Configured steps would spend more epsilon than the declared budget.
    pub const BUDGET_OVERSPEND: &str = "budget.overspend";
    /// Reference kernels would run on an ISA outside the bitwise-verified set.
    pub const KERNEL_UNVERIFIED_ISA: &str = "kernel.unverified-isa";
}

/// How severe a diagnostic is. Ordered most-severe-first so sorting a
/// report puts Deny findings at the top.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Serialize)]
#[serde(rename_all = "lowercase")]
pub enum Severity {
    /// Violates the DP/determinism contract; refuses to run.
    Deny,
    /// Executable but guarantee-free or weakened; surfaced only.
    Warn,
    /// Advisory.
    Info,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Deny => write!(f, "deny"),
            Severity::Warn => write!(f, "warn"),
            Severity::Info => write!(f, "info"),
        }
    }
}

/// One catalog entry: the fixed (id, severity) binding plus a summary
/// of what the rule proves (DESIGN.md §10 is generated from this list's
/// content, kept in sync by hand).
#[derive(Debug, Clone, Copy)]
pub struct RuleInfo {
    /// Stable identifier (see [`rule`]).
    pub id: &'static str,
    /// The severity every diagnostic with this id carries.
    pub severity: Severity,
    /// One-line summary of the property checked.
    pub summary: &'static str,
}

/// The full rule catalog.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: rule::CLIP_MISSING,
        severity: Severity::Deny,
        summary: "per-example-tainted values cross into a shared accumulator without any clip",
    },
    RuleInfo {
        id: rule::CLIP_PER_LAYER,
        severity: Severity::Deny,
        summary: "clip factor covers a strict subset of layers (per-layer clipping, wrong sensitivity)",
    },
    RuleInfo {
        id: rule::CLIP_NONPRIVATE,
        severity: Severity::Warn,
        summary: "nonprivate baseline: unclipped aggregation by design, no DP guarantee",
    },
    RuleInfo {
        id: rule::NOISE_MISSING,
        severity: Severity::Deny,
        summary: "no Gaussian noise site although the run claims sigma > 0",
    },
    RuleInfo {
        id: rule::NOISE_DOUBLE,
        severity: Severity::Deny,
        summary: "noise added more than once (miscalibrated total variance)",
    },
    RuleInfo {
        id: rule::NOISE_PRE_AGGREGATION,
        severity: Severity::Deny,
        summary: "noise injected before aggregation completes (per-rank/per-group noise)",
    },
    RuleInfo {
        id: rule::NOISE_SCALE,
        severity: Severity::Deny,
        summary: "noise stddev differs from the calibrated sigma * C",
    },
    RuleInfo {
        id: rule::NOISE_ZERO_SIGMA,
        severity: Severity::Warn,
        summary: "private variant with sigma = 0: epsilon is infinite",
    },
    RuleInfo {
        id: rule::STREAM_COLLISION,
        severity: Severity::Deny,
        summary: "two RNG uses share one (seed, stream, label) ChaCha tuple",
    },
    RuleInfo {
        id: rule::STREAM_EXHAUSTION,
        severity: Severity::Deny,
        summary: "a single stream's predicted draw exceeds its keystream capacity",
    },
    RuleInfo {
        id: rule::STREAM_LEGACY_EXHAUSTION,
        severity: Severity::Warn,
        summary: "draw exceeds the old 32-bit-counter capacity (silently corrupted before the widening)",
    },
    RuleInfo {
        id: rule::SHORTCUT_EPSILON,
        severity: Severity::Deny,
        summary: "non-Poisson sampler under Poisson (RDP/PLD) accounting — the shortcut epsilon",
    },
    RuleInfo {
        id: rule::SAMPLER_PER_RANK,
        severity: Severity::Deny,
        summary: "per-rank subsampling instead of one global draw per step",
    },
    RuleInfo {
        id: rule::RETRY_FRESH_DRAW,
        severity: Severity::Deny,
        summary: "step retry re-samples the Poisson mask or advances the noise stream (conditions the draw on failures, breaking both the accounted sampling distribution and bitwise recovery)",
    },
    RuleInfo {
        id: rule::REDUCE_SCHEDULE,
        severity: Severity::Deny,
        summary: "reduction is not the fixed tree whose shape depends only on the group count",
    },
    RuleInfo {
        id: rule::MATERIALIZED_PER_EXAMPLE,
        severity: Severity::Deny,
        summary: "a ghost/BK-contract variant materializes the [B, P] per-example gradient",
    },
    RuleInfo {
        id: rule::DTYPE_UNKNOWN,
        severity: Severity::Warn,
        summary: "unknown executable dtype; byte accounting would silently assume 4 bytes",
    },
    RuleInfo {
        id: rule::BUDGET_OVERSPEND,
        severity: Severity::Deny,
        summary: "the configured steps would spend more epsilon than the declared (epsilon, delta) budget under the chosen accountant",
    },
    RuleInfo {
        id: rule::KERNEL_UNVERIFIED_ISA,
        severity: Severity::Warn,
        summary: "reference kernels target an ISA outside the set whose lane/tree semantics are proven bitwise-equal to scalar (scalar/avx2/neon)",
    },
];

/// Look a rule up in the catalog.
pub fn catalog(id: &str) -> Option<&'static RuleInfo> {
    RULES.iter().find(|r| r.id == id)
}

/// One audit finding.
#[derive(Debug, Clone, Serialize)]
pub struct Diagnostic {
    /// Catalog rule id (see [`rule`]).
    pub rule: &'static str,
    /// Severity (always the catalog severity for `rule`).
    pub severity: Severity,
    /// Plan location, e.g. `layer[2].accumulate` or `plan.sampler`.
    pub location: String,
    /// Human explanation of the finding.
    pub message: String,
}

impl Diagnostic {
    /// Build a diagnostic; severity is looked up from the catalog.
    pub fn new(
        rule_id: &'static str,
        location: impl Into<String>,
        message: impl Into<String>,
    ) -> Self {
        let severity = catalog(rule_id).map(|r| r.severity).unwrap_or(Severity::Deny);
        Self { rule: rule_id, severity, location: location.into(), message: message.into() }
    }
}

/// The structured result of auditing one lowered run plan.
#[derive(Debug, Clone, Serialize)]
pub struct AuditReport {
    /// [`AUDIT_SCHEMA_VERSION`] at emission time.
    pub schema_version: u32,
    /// Model the plan trains.
    pub model: String,
    /// Accum variant the plan executes.
    pub variant: String,
    /// Sampler name (`poisson` | `shuffle`).
    pub sampler: String,
    /// Accountant name (`rdp` | `pld`).
    pub accountant: String,
    /// Data-parallel worker count of the plan.
    pub workers: usize,
    /// Optimizer steps the plan takes.
    pub steps: u64,
    /// Resolved noise multiplier.
    pub sigma: f64,
    /// Findings, most severe first.
    pub diagnostics: Vec<Diagnostic>,
}

impl AuditReport {
    /// Sort diagnostics most-severe-first, then by rule and location
    /// (stable, deterministic output).
    pub fn sort(&mut self) {
        self.diagnostics.sort_by(|a, b| {
            (a.severity, a.rule, &a.location).cmp(&(b.severity, b.rule, &b.location))
        });
    }

    /// Append diagnostics (e.g. from an HLO-text pass) and re-sort.
    pub fn push_all(&mut self, diags: Vec<Diagnostic>) {
        self.diagnostics.extend(diags);
        self.sort();
    }

    /// No Deny-severity findings?
    pub fn is_clean(&self) -> bool {
        self.diagnostics.iter().all(|d| d.severity != Severity::Deny)
    }

    /// Distinct rule ids of the Deny findings, in report order.
    pub fn deny_rules(&self) -> Vec<&'static str> {
        let mut out: Vec<&'static str> = self
            .diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Deny)
            .map(|d| d.rule)
            .collect();
        out.dedup();
        out
    }

    /// (deny, warn, info) counts.
    pub fn counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for d in &self.diagnostics {
            match d.severity {
                Severity::Deny => c.0 += 1,
                Severity::Warn => c.1 += 1,
                Severity::Info => c.2 += 1,
            }
        }
        c
    }

    /// Serialize for `dpshort audit --json`.
    pub fn to_json(&self) -> serde_json::Result<String> {
        serde_json::to_string_pretty(self)
    }

    /// Schema check: version matches, every rule is cataloged, and each
    /// diagnostic carries its catalog severity. Run before emitting
    /// `--json` output and by the fixture tests.
    pub fn validate(&self) -> Result<()> {
        if self.schema_version != AUDIT_SCHEMA_VERSION {
            return Err(anyhow!(
                "audit report schema v{} (expected v{AUDIT_SCHEMA_VERSION})",
                self.schema_version
            ));
        }
        for d in &self.diagnostics {
            let info = catalog(d.rule)
                .ok_or_else(|| anyhow!("diagnostic names unknown rule {:?}", d.rule))?;
            if info.severity != d.severity {
                return Err(anyhow!(
                    "rule {:?} carries severity {} (catalog says {})",
                    d.rule,
                    d.severity,
                    info.severity
                ));
            }
            if d.location.is_empty() || d.message.is_empty() {
                return Err(anyhow!("rule {:?}: empty location or message", d.rule));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(diags: Vec<Diagnostic>) -> AuditReport {
        AuditReport {
            schema_version: AUDIT_SCHEMA_VERSION,
            model: "m".into(),
            variant: "masked".into(),
            sampler: "poisson".into(),
            accountant: "rdp".into(),
            workers: 1,
            steps: 4,
            sigma: 1.0,
            diagnostics: diags,
        }
    }

    #[test]
    fn catalog_ids_are_unique_and_resolvable() {
        for (i, r) in RULES.iter().enumerate() {
            assert!(RULES[i + 1..].iter().all(|o| o.id != r.id), "duplicate {}", r.id);
            assert_eq!(catalog(r.id).unwrap().severity, r.severity);
        }
        assert!(catalog("no.such.rule").is_none());
    }

    #[test]
    fn sort_puts_deny_first() {
        let mut r = report(vec![
            Diagnostic::new(rule::DTYPE_UNKNOWN, "x", "warn thing"),
            Diagnostic::new(rule::CLIP_MISSING, "y", "deny thing"),
        ]);
        r.sort();
        assert_eq!(r.diagnostics[0].rule, rule::CLIP_MISSING);
        assert_eq!(r.counts(), (1, 1, 0));
        assert!(!r.is_clean());
        assert_eq!(r.deny_rules(), vec![rule::CLIP_MISSING]);
    }

    #[test]
    fn validate_rejects_unknown_rules_and_wrong_severity() {
        let ok = report(vec![Diagnostic::new(rule::NOISE_SCALE, "noise[0]", "off by 2x")]);
        ok.validate().unwrap();
        let mut bad = ok.clone();
        bad.diagnostics[0].rule = "made.up";
        assert!(bad.validate().is_err());
        let mut wrong = ok.clone();
        wrong.diagnostics[0].severity = Severity::Info;
        assert!(wrong.validate().is_err());
        let mut stale = ok;
        stale.schema_version = 99;
        assert!(stale.validate().is_err());
    }

    #[test]
    fn json_is_parseable_and_lowercase_severities() {
        let r = report(vec![Diagnostic::new(rule::SHORTCUT_EPSILON, "plan.sampler", "shuffle")]);
        let text = r.to_json().unwrap();
        let v: serde_json::Value = serde_json::from_str(&text).unwrap();
        assert_eq!(v["schema_version"], AUDIT_SCHEMA_VERSION);
        assert_eq!(v["diagnostics"][0]["severity"], "deny");
        assert_eq!(v["diagnostics"][0]["rule"], rule::SHORTCUT_EPSILON);
    }
}
