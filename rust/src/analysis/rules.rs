//! The audit rule engine: judge a lowered [`RunPlan`] (and optionally
//! an HLO-text dump) against the catalog in [`crate::analysis::diag`].

use crate::analysis::diag::{rule, AuditReport, Diagnostic, AUDIT_SCHEMA_VERSION};
use crate::analysis::plan::{variant_claims_no_materialization, ClipKind, NoiseStage, RunPlan};
use crate::analysis::streams;
use crate::analysis::taint::{propagate, Graph, NodeKind, Taint};
use crate::models::LayerKind;
use crate::runtime::hlo_analysis::{dtype_bytes, HloStats};
use crate::util::rng::LEGACY_STREAM_CAPACITY_BYTES;
use std::collections::BTreeSet;

/// Audit a plan end to end (lowers the canonical taint graph itself).
pub fn audit_plan(plan: &RunPlan) -> AuditReport {
    audit_plan_graph(plan, &Graph::lower(plan))
}

/// Audit a plan against an explicitly supplied dataflow graph (the
/// fixture suite mutates graphs directly to model miscompiled steps).
pub fn audit_plan_graph(plan: &RunPlan, g: &Graph) -> AuditReport {
    let mut d = Vec::new();
    check_clipping(plan, g, &mut d);
    check_noise(plan, g, &mut d);
    check_streams(plan, &mut d);
    check_accounting(plan, &mut d);
    check_retry(plan, &mut d);
    check_budget(plan, &mut d);
    check_topology(plan, g, &mut d);
    check_materialization(plan, g, &mut d);
    check_dtypes(plan, &mut d);
    check_kernel(plan, &mut d);
    let mut report = AuditReport {
        schema_version: AUDIT_SCHEMA_VERSION,
        model: plan.model.clone(),
        variant: plan.variant.clone(),
        sampler: plan.sampler.choice.as_str().to_string(),
        accountant: plan.accountant.as_str().to_string(),
        workers: plan.workers,
        steps: plan.steps,
        sigma: plan.sigma,
        diagnostics: d,
    };
    report.sort();
    report
}

/// (a) Per-example taint must cross into shared accumulators only
/// through exactly one global-norm clip.
fn check_clipping(plan: &RunPlan, g: &Graph, d: &mut Vec<Diagnostic>) {
    let analysis = propagate(g);
    let all: BTreeSet<usize> = (0..plan.layer_dims.len()).collect();
    let mut nonprivate_flagged = false;
    for (node, taint) in &analysis.crossings {
        let NodeKind::Accumulate { layer } = g.nodes[*node] else { continue };
        let Taint::PerExample { cover } = taint else { continue };
        if *cover == all {
            continue; // clipped by the global norm over every layer
        }
        if cover.is_empty() {
            if plan.private {
                d.push(Diagnostic::new(
                    rule::CLIP_MISSING,
                    format!("layer[{layer}].accumulate"),
                    format!(
                        "per-example gradient of layer {layer} reaches the shared accumulator \
                         without passing any clip; DP-SGD requires exactly one global-norm clip \
                         before aggregation"
                    ),
                ));
            } else if !nonprivate_flagged {
                nonprivate_flagged = true;
                d.push(Diagnostic::new(
                    rule::CLIP_NONPRIVATE,
                    "plan.clip",
                    format!(
                        "variant {:?} aggregates unclipped per-example gradients by design: \
                         the run carries no differential-privacy guarantee (epsilon = infinity)",
                        plan.variant
                    ),
                ));
            }
        } else {
            let missing: Vec<usize> = all.difference(cover).copied().collect();
            d.push(Diagnostic::new(
                rule::CLIP_PER_LAYER,
                format!("layer[{layer}].accumulate"),
                format!(
                    "layer {layer}'s gradient is scaled by a clip factor derived from the norms \
                     of layers {:?} only (missing {missing:?}); per-layer clipping changes the \
                     mechanism's sensitivity and voids the global-norm accounting",
                    cover.iter().collect::<Vec<_>>()
                ),
            ));
        }
    }
    // (a, continued) Group-level norm completeness, judged structurally.
    // The taint cover is layer-granular: an attention layer whose norm
    // omits ONE of its four Gram products (q/k/v/o) still inserts its
    // layer index through the remaining three, so the cover looks
    // complete. Under a global clip, every Gram node must therefore
    // *reach* the clip factor along dataflow edges; an orphaned group
    // means the clip norm under-counts that layer and the sensitivity
    // bound is void — the same defect class as per-layer clipping.
    if plan.private && plan.clip.kind == ClipKind::Global {
        let factors: Vec<usize> = (0..g.nodes.len())
            .filter(|&i| matches!(g.nodes[i], NodeKind::ClipFactor))
            .collect();
        for i in 0..g.nodes.len() {
            let NodeKind::GramNorm { layer, group } = g.nodes[i] else { continue };
            if !factors.iter().any(|&f| g.reaches(i, f)) {
                let kind = plan.layer_kinds.get(layer).copied().unwrap_or(LayerKind::Dense);
                d.push(Diagnostic::new(
                    rule::CLIP_PER_LAYER,
                    format!("layer[{layer}].gram[{group}]"),
                    format!(
                        "parameter group {group} of {} layer {layer} computes a per-example \
                         Gram norm that never flows into the clip factor; the \"global\" norm \
                         under-counts this layer's gradient and the clip no longer bounds the \
                         mechanism's sensitivity",
                        kind.as_str()
                    ),
                ));
            }
        }
    }
}

fn approx_eq(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * b.abs().max(1.0)
}

/// (b) Gaussian noise: exactly once, post-aggregation, scale sigma·C.
fn check_noise(plan: &RunPlan, g: &Graph, d: &mut Vec<Diagnostic>) {
    let noise_nodes: Vec<usize> = (0..g.nodes.len())
        .filter(|&i| matches!(g.nodes[i], NodeKind::Noise { .. }))
        .collect();
    if plan.private && plan.sigma <= 0.0 {
        d.push(Diagnostic::new(
            rule::NOISE_ZERO_SIGMA,
            "plan.noise",
            "private variant with sigma = 0: no Gaussian noise is added, so the run has no \
             finite epsilon (useful for mechanics benches only)",
        ));
    }
    let expected = plan.private && plan.sigma > 0.0;
    if expected && noise_nodes.is_empty() {
        d.push(Diagnostic::new(
            rule::NOISE_MISSING,
            "plan.noise",
            format!(
                "the run claims sigma = {} but the plan contains no Gaussian noise site after \
                 the reduction; the reported epsilon would be fiction",
                plan.sigma
            ),
        ));
    }
    if expected && noise_nodes.len() > 1 {
        d.push(Diagnostic::new(
            rule::NOISE_DOUBLE,
            "plan.noise",
            format!(
                "{} Gaussian noise sites in the plan; noise must be added exactly once \
                 (injecting per rank or per site multiplies the total variance and breaks the \
                 sigma*C calibration)",
                noise_nodes.len()
            ),
        ));
    }
    if !expected {
        return;
    }
    let want = plan.sigma * plan.clip.norm;
    for &i in &noise_nodes {
        let NodeKind::Noise { site } = g.nodes[i] else { continue };
        // Pre-aggregation: the noise value flows INTO an aggregation
        // node instead of being added after the final reduce.
        let feeds_aggregation = (0..g.nodes.len()).any(|j| {
            matches!(
                g.nodes[j],
                NodeKind::Accumulate { .. } | NodeKind::Partial | NodeKind::Reduce { .. }
            ) && g.reaches(i, j)
        });
        if feeds_aggregation {
            d.push(Diagnostic::new(
                rule::NOISE_PRE_AGGREGATION,
                format!("noise[{site}]"),
                "noise is injected before aggregation completes (per-group/per-rank noise); \
                 the mechanism analysed adds one draw to the final aggregated gradient",
            ));
        }
        if let Some(ns) = plan.noise.get(site) {
            if !approx_eq(ns.scale, want) {
                d.push(Diagnostic::new(
                    rule::NOISE_SCALE,
                    format!("noise[{site}]"),
                    format!(
                        "noise stddev {} != sigma * C = {} * {} = {want}; the accountant prices \
                         exactly sigma*C",
                        ns.scale, plan.sigma, plan.clip.norm
                    ),
                ));
            }
        }
    }
}

/// Upper bound (bytes) on the largest single-stream draw of the run,
/// with the purpose of the stream that attains it. 16 bytes per drawn
/// value is a generous over-estimate (normal draws consume two u64s).
fn max_stream_draw_bytes(plan: &RunPlan) -> (u128, &'static str) {
    let candidates: [(u128, &'static str); 3] = [
        (16 * plan.n_params as u128, "noise.apply"),
        (16 * u128::from(plan.dataset_size), "sampler"),
        (16 * plan.input_dim as u128, "data.example"),
    ];
    candidates
        .into_iter()
        .max_by_key(|(b, _)| *b)
        .expect("non-empty candidate list")
}

/// (b, continued) Stream hygiene: `(seed, stream, label)` injectivity
/// plus statically-predictable keystream exhaustion.
fn check_streams(plan: &RunPlan, d: &mut Vec<Diagnostic>) {
    for (a, b) in streams::find_collisions(&plan.streams) {
        d.push(Diagnostic::new(
            rule::STREAM_COLLISION,
            format!("stream[{}]", a.label_str()),
            format!(
                "{} and {} construct the same ChaCha key (seed={}, stream={}, label={:?}): \
                 correlated draws across consumers (e.g. noise correlated with sampling) void \
                 the Gaussian-mechanism analysis",
                a.purpose,
                b.purpose,
                a.seed,
                a.stream,
                a.label_str()
            ),
        ));
    }
    // 64 bytes per block, 2^counter_bits blocks.
    let capacity: u128 = 64u128 << plan.rng_counter_bits.min(120);
    let (draw, purpose) = max_stream_draw_bytes(plan);
    if draw > capacity {
        d.push(Diagnostic::new(
            rule::STREAM_EXHAUSTION,
            format!("stream[{purpose}]"),
            format!(
                "the {purpose} stream draws up to {draw} bytes but a {}-bit block counter \
                 yields only {capacity} keystream bytes; the generator would reuse (or abort \
                 on) exhausted keystream mid-run",
                plan.rng_counter_bits
            ),
        ));
    } else if draw > LEGACY_STREAM_CAPACITY_BYTES {
        d.push(Diagnostic::new(
            rule::STREAM_LEGACY_EXHAUSTION,
            format!("stream[{purpose}]"),
            format!(
                "the {purpose} stream draws up to {draw} bytes, past the 2^38-byte capacity of \
                 the pre-widening 32-bit block counter; runs at this scale silently reused \
                 keystream before the counter was widened to 64 bits"
            ),
        ));
    }
}

/// (c) The accountant must match the sampler.
fn check_accounting(plan: &RunPlan, d: &mut Vec<Diagnostic>) {
    if plan.private && plan.sampler.poisson_rate.is_none() {
        d.push(Diagnostic::new(
            rule::SHORTCUT_EPSILON,
            "plan.sampler",
            format!(
                "sampler {:?} provides no Poisson rate, but the {} accountant analyses the \
                 Poisson-subsampled Gaussian mechanism; reporting its epsilon for this run is \
                 the \"shortcut epsilon\" of arXiv 2403.17673 / 2411.04205, not a guarantee",
                plan.sampler.choice.as_str(),
                plan.accountant.as_str()
            ),
        ));
    }
    if plan.sampler.per_rank {
        d.push(Diagnostic::new(
            rule::SAMPLER_PER_RANK,
            "plan.sampler",
            "each rank draws its own subsample; the sampled mechanism requires ONE global draw \
             per step, sharded deterministically across ranks",
        ));
    }
}

/// (c, continued) A step retry must replay the step it failed on: same
/// Poisson mask, same noise `(seed, stream)` tuple (DESIGN.md §11).
/// Re-drawing either conditions the published randomness on failure
/// events — the accounted sampling distribution no longer holds (the
/// retry analogue of the shortcut epsilon) and recovery stops being
/// bitwise-identical to the fault-free run.
fn check_retry(plan: &RunPlan, d: &mut Vec<Diagnostic>) {
    if plan.retry.resample_on_retry {
        d.push(Diagnostic::new(
            rule::RETRY_FRESH_DRAW,
            "plan.retry",
            "the retry policy re-samples the per-step Poisson mask on step retry; the \
             accountant prices one draw per step, so conditioning a fresh draw on a failure \
             changes the sampling distribution it analysed (and the recovered trajectory \
             diverges from the fault-free run)",
        ));
    }
    if plan.retry.fresh_noise_on_retry {
        d.push(Diagnostic::new(
            rule::RETRY_FRESH_DRAW,
            "plan.retry.noise",
            "the retry policy advances the noise stream on step retry; a retried step must \
             reuse the same (seed, stream) noise tuple or the epsilon spend no longer \
             describes the mechanism that ran (one noise draw priced, two consumed)",
        ));
    }
}

/// (c, continued) A declared `(epsilon, delta)` budget must cover the
/// configured steps — the serve admission contract. Priced with the
/// plan's own accountant over its Poisson rate; a plan whose sampler
/// provides no rate is already denied by `accountant.shortcut-epsilon`,
/// so pricing is skipped there rather than double-flagged.
fn check_budget(plan: &RunPlan, d: &mut Vec<Diagnostic>) {
    let Some(budget) = plan.budget else { return };
    if !plan.private {
        return;
    }
    let Some(q) = plan.sampler.poisson_rate else { return };
    let spend = plan.accountant.epsilon_after(q, plan.sigma, plan.steps, budget.delta);
    if spend > budget.epsilon && !approx_eq(spend, budget.epsilon) {
        d.push(Diagnostic::new(
            rule::BUDGET_OVERSPEND,
            "plan.budget",
            format!(
                "{} steps at (q={q}, sigma={}) spend epsilon = {spend:.4} under the {} \
                 accountant, exceeding the declared budget epsilon = {} at delta = {}; admit \
                 fewer steps or declare a larger budget",
                plan.steps,
                plan.sigma,
                plan.accountant.as_str(),
                budget.epsilon,
                budget.delta
            ),
        ));
    }
}

/// (d) The reduction must be schedule-invariant.
fn check_topology(plan: &RunPlan, g: &Graph, d: &mut Vec<Diagnostic>) {
    if plan.reduction.worker_dependent {
        d.push(Diagnostic::new(
            rule::REDUCE_SCHEDULE,
            "plan.reduce",
            "the reduction order depends on the worker schedule; gradients must combine through \
             the fixed binary tree whose shape is a function of the group count only (the \
             bitwise-determinism contract)",
        ));
    }
    for (i, k) in g.nodes.iter().enumerate() {
        if matches!(k, NodeKind::Reduce { fixed_tree: false }) {
            d.push(Diagnostic::new(
                rule::REDUCE_SCHEDULE,
                format!("reduce[{i}]"),
                "a reduce node is not the fixed-tree combine; float addition is not \
                 associative, so any schedule-dependent order breaks bitwise reproducibility",
            ));
        }
    }
}

/// Satellite: the `[B, P]` materialization contract, judged on the
/// lowered layer graph (the HLO-text form is [`audit_hlo`]).
fn check_materialization(plan: &RunPlan, g: &Graph, d: &mut Vec<Diagnostic>) {
    if !variant_claims_no_materialization(&plan.variant) {
        return;
    }
    for k in &g.nodes {
        if let NodeKind::LayerGrad { layer, materialized: true } = k {
            let kind = plan.layer_kinds.get(*layer).copied().unwrap_or(LayerKind::Dense);
            d.push(Diagnostic::new(
                rule::MATERIALIZED_PER_EXAMPLE,
                format!("layer[{layer}].grad"),
                format!(
                    "variant {:?} promises per-example weight gradients are never materialized, \
                     but {} layer {layer} materializes its per-example weight-gradient block \
                     (the [B, P] memory footprint ghost/BK exist to avoid)",
                    plan.variant,
                    kind.as_str()
                ),
            ));
        }
    }
}

/// Satellite: unknown executable dtypes would silently be priced at 4
/// bytes by the memory model.
fn check_dtypes(plan: &RunPlan, d: &mut Vec<Diagnostic>) {
    for ty in &plan.dtypes {
        if dtype_bytes(ty).is_none() {
            d.push(Diagnostic::new(
                rule::DTYPE_UNKNOWN,
                format!("executables.dtype={ty}"),
                format!(
                    "executable dtype {ty:?} is unknown to the memory model; byte accounting \
                     would silently assume 4 bytes per element"
                ),
            ));
        }
    }
}

/// Satellite: the reference kernels' ISA must be one whose lane/tree
/// semantics are pinned bitwise-equal to scalar by the kernel test
/// battery (`runtime::kernels::VERIFIED_ISAS`). The kernel choice is a
/// wall-clock knob, so an unknown ISA is Warn, not Deny — but bits on
/// such a host carry no cross-ISA reproducibility claim until the
/// battery covers it.
fn check_kernel(plan: &RunPlan, d: &mut Vec<Diagnostic>) {
    use crate::runtime::kernels::VERIFIED_ISAS;
    if !VERIFIED_ISAS.contains(&plan.kernel_isa.as_str()) {
        d.push(Diagnostic::new(
            rule::KERNEL_UNVERIFIED_ISA,
            "plan.kernel",
            format!(
                "reference kernels would execute with ISA {:?}, which is outside the \
                 bitwise-verified set {VERIFIED_ISAS:?}; run with --kernel scalar (or extend \
                 the kernel battery) to keep the cross-host determinism claim",
                plan.kernel_isa
            ),
        ));
    }
}

/// Audit an HLO-text dump against the structural rules: unknown dtypes
/// plus the `[B, P]` per-example-materialization tensor under a variant
/// whose contract forbids it.
pub fn audit_hlo(
    stats: &HloStats,
    batch: usize,
    n_params: usize,
    variant: &str,
) -> Vec<Diagnostic> {
    let mut d = Vec::new();
    for ty in &stats.unknown_dtypes {
        d.push(Diagnostic::new(
            rule::DTYPE_UNKNOWN,
            format!("hlo.dtype={ty}"),
            format!(
                "HLO declares tensors of unknown dtype {ty:?}; byte accounting assumed 4 bytes \
                 per element for them"
            ),
        ));
    }
    let materialized = stats.has_tensor(&[batch as u64, n_params as u64]);
    if variant_claims_no_materialization(variant) && materialized {
        d.push(Diagnostic::new(
            rule::MATERIALIZED_PER_EXAMPLE,
            format!("hlo.tensor[{batch},{n_params}]"),
            format!(
                "the HLO materializes a [{batch}, {n_params}] per-example gradient tensor, but \
                 variant {variant:?} promises it never exists"
            ),
        ));
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::plan::test_plan;

    #[test]
    fn clean_fixture_plan_audits_clean() {
        let plan = test_plan(3);
        let report = audit_plan(&plan);
        report.validate().unwrap();
        assert!(report.is_clean(), "diags: {:?}", report.diagnostics);
        assert_eq!(report.counts(), (0, 0, 0));
    }

    #[test]
    fn fresh_draw_on_retry_is_denied() {
        let mut plan = test_plan(2);
        plan.retry.resample_on_retry = true;
        let report = audit_plan(&plan);
        report.validate().unwrap();
        assert!(report.deny_rules().contains(&rule::RETRY_FRESH_DRAW));

        let mut noise = test_plan(2);
        noise.retry.fresh_noise_on_retry = true;
        let report = audit_plan(&noise);
        assert!(report.deny_rules().contains(&rule::RETRY_FRESH_DRAW));
    }

    #[test]
    fn declared_budget_gates_on_priced_spend() {
        use crate::analysis::plan::BudgetSpec;
        // test_plan(3): q = 0.25, sigma = 1.0, steps = 4, RDP. A budget
        // above the priced spend stays clean; one below is denied.
        let plan = test_plan(3);
        let spend =
            plan.accountant.epsilon_after(0.25, 1.0, 4, 1e-5);
        assert!(spend.is_finite() && spend > 0.0);

        let mut roomy = test_plan(3);
        roomy.budget = Some(BudgetSpec { epsilon: spend * 2.0, delta: 1e-5 });
        assert!(audit_plan(&roomy).is_clean());

        let mut tight = test_plan(3);
        tight.budget = Some(BudgetSpec { epsilon: spend * 0.5, delta: 1e-5 });
        let report = audit_plan(&tight);
        report.validate().unwrap();
        assert_eq!(report.deny_rules(), vec![rule::BUDGET_OVERSPEND]);

        // No declared budget: spend is never judged.
        assert!(audit_plan(&test_plan(3)).is_clean());
    }

    #[test]
    fn unverified_kernel_isa_warns_but_never_denies() {
        let mut plan = test_plan(2);
        plan.kernel_isa = "avx512".into();
        let report = audit_plan(&plan);
        report.validate().unwrap();
        assert!(report.is_clean(), "wall-clock knob: Warn, not Deny");
        assert_eq!(report.counts().1, 1, "diags: {:?}", report.diagnostics);
        assert_eq!(report.diagnostics[0].rule, rule::KERNEL_UNVERIFIED_ISA);

        // Every battery-pinned ISA stays silent.
        for isa in crate::runtime::kernels::VERIFIED_ISAS {
            let mut plan = test_plan(2);
            plan.kernel_isa = (*isa).into();
            assert_eq!(audit_plan(&plan).counts(), (0, 0, 0), "{isa}");
        }
    }

    #[test]
    fn approx_eq_tolerates_rounding_only() {
        assert!(approx_eq(1.0 + 1e-12, 1.0));
        assert!(!approx_eq(1.5, 1.0));
        assert!(!approx_eq(2e-9, 1e-9 * 0.5));
    }
}
