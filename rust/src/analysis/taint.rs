//! Per-example taint analysis over the lowered step dataflow.
//!
//! The lattice has two levels. `Shared` (bottom) marks values that are
//! functions of the whole batch/run only; `PerExample { cover }` marks
//! values carrying information about *individual* examples, where
//! `cover` records which layers' Gram norms have been folded into the
//! value's scaling so far. The join is `Shared ⊔ x = x` and
//! `PerExample{a} ⊔ PerExample{b} = PerExample{a ∪ b}` — finite and
//! monotone, so fixpoint propagation over the (acyclic) step graph
//! terminates.
//!
//! The DP contract is then a statement about **accumulate nodes** (the
//! example-crossing points where per-example contributions fold into a
//! shared sum): the incoming taint must either be `Shared`, or be
//! `PerExample` with `cover == {all layers}` — i.e. the value was
//! scaled by a clip factor derived from the **global** norm over every
//! layer. `cover == ∅` means no clip at all (the `clip.missing` /
//! `clip.nonprivate` rules); a strict subset means per-layer clipping
//! (`clip.per-layer`), which changes the mechanism's sensitivity.
//!
//! The cover is *layer*-granular, but the lowered graph is finer: each
//! layer contributes one [`NodeKind::GramNorm`] node per parameter
//! group (attention contributes four). Dropping a single group's edge
//! into the norm total leaves the layer-level cover intact — the
//! remaining groups still insert the layer — so group-level norm
//! completeness is judged structurally by the clipping rule
//! (`reaches` from every Gram node to the clip factor), not by taint.

use crate::analysis::plan::{gram_groups, ClipKind, NoiseStage, RunPlan};
use crate::clipping::LayerChoice;
use crate::models::LayerKind;
use std::collections::BTreeSet;

/// Node kinds of the lowered step dataflow graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NodeKind {
    /// The batch of example inputs (taint source).
    ExampleInput,
    /// Layer `l`'s forward tape (activations).
    Tape {
        /// Layer index.
        layer: usize,
    },
    /// Layer `l`'s backward pre-activation gradients (dz).
    Backward {
        /// Layer index.
        layer: usize,
    },
    /// One parameter group's per-example squared gradient norm (Gram
    /// form). Dense/conv/layernorm layers fold a single group; an
    /// attention layer folds four (q/k/v projections against the layer
    /// input, output projection against the context rows), and the
    /// global norm is only complete if every group's node flows into
    /// the clip factor.
    GramNorm {
        /// Layer index.
        layer: usize,
        /// Parameter-group index within the layer (`0..gram_groups`).
        group: usize,
    },
    /// The total per-example norm (sum of Gram norms feeding the clip).
    NormTotal,
    /// The per-example clip factor `min(1, C / ||g_i||)`.
    ClipFactor,
    /// Layer `l`'s (possibly reweighted) per-example gradient.
    LayerGrad {
        /// Layer index.
        layer: usize,
        /// Whether the `[B, d_out * d_in]` per-example weight gradient
        /// is materialized (per-example branch) or folded fused (ghost).
        materialized: bool,
    },
    /// Layer `l`'s shared accumulator — an example-crossing point.
    Accumulate {
        /// Layer index.
        layer: usize,
    },
    /// One group's partial gradient sum.
    Partial,
    /// The cross-group reduction combining partials.
    Reduce {
        /// Whether the reduction is the fixed binary tree whose shape
        /// depends only on the group count.
        fixed_tree: bool,
    },
    /// Gaussian noise injection for plan noise site `site`.
    Noise {
        /// Index into [`RunPlan::noise`].
        site: usize,
    },
    /// The optimizer update consuming the final gradient.
    Update,
}

/// The step dataflow graph (adjacency as an edge list; fields public so
/// adversarial fixtures can mutate the lowered graph directly).
#[derive(Debug, Clone, Default)]
pub struct Graph {
    /// Node kinds, indexed by node id.
    pub nodes: Vec<NodeKind>,
    /// Directed `(from, to)` dataflow edges.
    pub edges: Vec<(usize, usize)>,
}

impl Graph {
    /// Append a node, returning its id.
    pub fn push(&mut self, kind: NodeKind) -> usize {
        self.nodes.push(kind);
        self.nodes.len() - 1
    }

    /// Add a dataflow edge.
    pub fn edge(&mut self, from: usize, to: usize) {
        self.edges.push((from, to));
    }

    /// Is `to` reachable from `from` along dataflow edges?
    pub fn reaches(&self, from: usize, to: usize) -> bool {
        let n = self.nodes.len();
        let mut seen = vec![false; n];
        let mut stack = vec![from];
        while let Some(i) = stack.pop() {
            if i == to {
                return true;
            }
            if i >= n || seen[i] {
                continue;
            }
            seen[i] = true;
            for &(f, t) in &self.edges {
                if f == i && !seen.get(t).copied().unwrap_or(true) {
                    stack.push(t);
                }
            }
        }
        false
    }

    /// Lower a [`RunPlan`] to its canonical step dataflow: input →
    /// forward tapes → backward chain → per-layer Gram norms → clip
    /// factor (per the plan's [`ClipKind`]) → reweighted layer grads →
    /// per-layer accumulators → group partial → fixed-tree reduce →
    /// noise (per the plan's sites) → update.
    pub fn lower(plan: &RunPlan) -> Graph {
        let mut g = Graph::default();
        let k = plan.layer_dims.len();
        let input = g.push(NodeKind::ExampleInput);

        // Forward tapes chain input → tape_0 → ... → tape_{k-1}.
        let mut tapes = Vec::with_capacity(k);
        let mut prev = input;
        for l in 0..k {
            let t = g.push(NodeKind::Tape { layer: l });
            g.edge(prev, t);
            tapes.push(t);
            prev = t;
        }

        // Backward chain head → 0; each dz_l reads the tape above it.
        let mut backs = vec![0usize; k];
        let mut prev_back: Option<usize> = None;
        for l in (0..k).rev() {
            let b = g.push(NodeKind::Backward { layer: l });
            g.edge(tapes[l], b);
            if let Some(pb) = prev_back {
                g.edge(pb, b);
            }
            backs[l] = b;
            prev_back = Some(b);
        }

        // Per-layer Gram norms (tape ⊗ dz), one node per parameter
        // group of the layer's kind, then the clip factor.
        let mut grams: Vec<Vec<usize>> = Vec::with_capacity(k);
        for l in 0..k {
            let kind = plan.layer_kinds.get(l).copied().unwrap_or(LayerKind::Dense);
            let mut groups = Vec::with_capacity(gram_groups(kind));
            for group in 0..gram_groups(kind) {
                let gn = g.push(NodeKind::GramNorm { layer: l, group });
                g.edge(tapes[l], gn);
                g.edge(backs[l], gn);
                groups.push(gn);
            }
            grams.push(groups);
        }
        // factor_for[l]: the clip factor scaling layer l's gradient.
        let factor_for: Vec<Option<usize>> = match plan.clip.kind {
            ClipKind::Global => {
                let total = g.push(NodeKind::NormTotal);
                for &gn in grams.iter().flatten() {
                    g.edge(gn, total);
                }
                let f = g.push(NodeKind::ClipFactor);
                g.edge(total, f);
                vec![Some(f); k]
            }
            ClipKind::PerLayer => (0..k)
                .map(|l| {
                    // Each layer clipped by ITS OWN norm only — the
                    // wrong-sensitivity shortcut the audit flags.
                    let f = g.push(NodeKind::ClipFactor);
                    for &gn in &grams[l] {
                        g.edge(gn, f);
                    }
                    Some(f)
                })
                .collect(),
            ClipKind::Unclipped => vec![None; k],
        };

        // Reweighted layer grads → per-layer accumulators.
        let mut accs = Vec::with_capacity(k);
        for l in 0..k {
            let materialized = plan
                .choices
                .get(l)
                .is_some_and(|c| *c == LayerChoice::PerExample);
            let lg = g.push(NodeKind::LayerGrad { layer: l, materialized });
            g.edge(tapes[l], lg);
            g.edge(backs[l], lg);
            if let Some(f) = factor_for[l] {
                g.edge(f, lg);
            }
            let a = g.push(NodeKind::Accumulate { layer: l });
            g.edge(lg, a);
            accs.push(a);
        }

        // Group partial → cross-group reduce → noise site(s) → update.
        let partial = g.push(NodeKind::Partial);
        for &a in &accs {
            g.edge(a, partial);
        }
        let reduce = g.push(NodeKind::Reduce { fixed_tree: plan.reduction.fixed_tree });
        g.edge(partial, reduce);
        let update = g.push(NodeKind::Update);
        let mut tail = reduce;
        for (site, ns) in plan.noise.iter().enumerate() {
            let nz = g.push(NodeKind::Noise { site });
            match ns.stage {
                NoiseStage::PostAggregation => {
                    g.edge(tail, nz);
                    tail = nz;
                }
                NoiseStage::PreAggregation => {
                    // Noise injected into each group's partial — the
                    // per-rank-noise miscalibration shape.
                    g.edge(nz, partial);
                }
            }
        }
        g.edge(tail, update);
        g
    }
}

/// Taint lattice value.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Taint {
    /// Function of the batch/run as a whole (bottom).
    Shared,
    /// Carries per-example information; `cover` is the set of layers
    /// whose Gram norms have been folded into the value's scaling.
    PerExample {
        /// Layers covered by the clip this value passed through.
        cover: BTreeSet<usize>,
    },
}

/// Lattice join.
fn join(a: &Taint, b: &Taint) -> Taint {
    match (a, b) {
        (Taint::Shared, x) | (x, Taint::Shared) => x.clone(),
        (Taint::PerExample { cover: ca }, Taint::PerExample { cover: cb }) => Taint::PerExample {
            cover: ca.union(cb).cloned().collect(),
        },
    }
}

/// Per-node transfer function over the joined input taint.
fn transfer(kind: &NodeKind, input: &Taint) -> Taint {
    match kind {
        NodeKind::ExampleInput => Taint::PerExample { cover: BTreeSet::new() },
        NodeKind::GramNorm { layer, .. } => match input {
            Taint::PerExample { cover } => {
                let mut c = cover.clone();
                c.insert(*layer);
                Taint::PerExample { cover: c }
            }
            Taint::Shared => Taint::Shared,
        },
        // Example-crossing / group-crossing aggregations output shared
        // values; the *incoming* taint is what the rules inspect.
        NodeKind::Accumulate { .. } | NodeKind::Reduce { .. } => Taint::Shared,
        _ => input.clone(),
    }
}

/// Fixpoint result: the out-taint of every node plus the joined
/// *incoming* taint at each accumulate node (the crossing evidence the
/// clipping rules judge).
#[derive(Debug, Clone)]
pub struct TaintAnalysis {
    /// Out-taint per node id.
    pub taints: Vec<Taint>,
    /// `(accumulate node id, joined incoming taint)` per crossing.
    pub crossings: Vec<(usize, Taint)>,
}

/// Run the taint fixpoint over `g`.
pub fn propagate(g: &Graph) -> TaintAnalysis {
    let n = g.nodes.len();
    let mut ins: Vec<Vec<usize>> = vec![Vec::new(); n];
    for &(f, t) in &g.edges {
        if f < n && t < n {
            ins[t].push(f);
        }
    }
    let mut taints = vec![Taint::Shared; n];
    // The lattice is finite and the transfer monotone; n + 1 sweeps
    // bound any chain through an acyclic graph (and terminate even on
    // adversarially cyclic fixture graphs).
    for _sweep in 0..=n {
        let mut changed = false;
        for i in 0..n {
            let joined = ins[i]
                .iter()
                .fold(Taint::Shared, |acc, &p| join(&acc, &taints[p]));
            let out = transfer(&g.nodes[i], &joined);
            if out != taints[i] {
                taints[i] = out;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    let crossings = (0..n)
        .filter(|&i| matches!(g.nodes[i], NodeKind::Accumulate { .. }))
        .map(|i| {
            let joined = ins[i]
                .iter()
                .fold(Taint::Shared, |acc, &p| join(&acc, &taints[p]));
            (i, joined)
        })
        .collect();
    TaintAnalysis { taints, crossings }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cover(layers: &[usize]) -> Taint {
        Taint::PerExample { cover: layers.iter().copied().collect() }
    }

    #[test]
    fn join_is_commutative_union() {
        assert_eq!(join(&Taint::Shared, &cover(&[1])), cover(&[1]));
        assert_eq!(join(&cover(&[0]), &cover(&[1])), cover(&[0, 1]));
        assert_eq!(join(&Taint::Shared, &Taint::Shared), Taint::Shared);
    }

    #[test]
    fn global_clip_covers_all_layers_at_every_crossing() {
        use crate::analysis::plan::test_plan;
        let plan = test_plan(3);
        let g = Graph::lower(&plan);
        let analysis = propagate(&g);
        let all: BTreeSet<usize> = (0..3).collect();
        assert_eq!(analysis.crossings.len(), 3);
        for (node, taint) in &analysis.crossings {
            assert!(matches!(g.nodes[*node], NodeKind::Accumulate { .. }));
            assert_eq!(*taint, Taint::PerExample { cover: all.clone() });
        }
        // Post-reduce values are shared; the noise node sees Shared in.
        let update = g
            .nodes
            .iter()
            .position(|k| *k == NodeKind::Update)
            .unwrap();
        assert_eq!(analysis.taints[update], Taint::Shared);
    }

    #[test]
    fn unclipped_crossings_have_empty_cover() {
        use crate::analysis::plan::{test_plan, ClipKind};
        let mut plan = test_plan(2);
        plan.clip.kind = ClipKind::Unclipped;
        let g = Graph::lower(&plan);
        for (_, taint) in propagate(&g).crossings {
            assert_eq!(taint, cover(&[]));
        }
    }

    #[test]
    fn per_layer_clip_covers_only_its_own_layer() {
        use crate::analysis::plan::{test_plan, ClipKind};
        let mut plan = test_plan(2);
        plan.clip.kind = ClipKind::PerLayer;
        let g = Graph::lower(&plan);
        let analysis = propagate(&g);
        for (node, taint) in analysis.crossings {
            let NodeKind::Accumulate { layer } = g.nodes[node] else {
                panic!("crossing at a non-accumulate node")
            };
            assert_eq!(taint, cover(&[layer]), "layer {layer}");
        }
    }

    #[test]
    fn attention_layers_lower_one_gram_node_per_parameter_group() {
        use crate::analysis::plan::test_plan;
        let mut plan = test_plan(2);
        plan.layer_kinds[0] = LayerKind::Attention { t: 2, d_model: 4, d_head: 2 };
        let g = Graph::lower(&plan);
        let att: Vec<usize> = (0..g.nodes.len())
            .filter(|&i| matches!(g.nodes[i], NodeKind::GramNorm { layer: 0, .. }))
            .collect();
        let att_groups: Vec<usize> = att
            .iter()
            .map(|&i| match g.nodes[i] {
                NodeKind::GramNorm { group, .. } => group,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(att_groups, vec![0, 1, 2, 3], "q/k/v/o Gram products");
        let dense: Vec<usize> = (0..g.nodes.len())
            .filter(|&i| matches!(g.nodes[i], NodeKind::GramNorm { layer: 1, .. }))
            .collect();
        assert_eq!(dense.len(), 1);
        // Every group feeds the global norm total...
        let total = g.nodes.iter().position(|k| *k == NodeKind::NormTotal).unwrap();
        for &gn in att.iter().chain(dense.iter()) {
            assert!(g.reaches(gn, total));
        }
        // ...and the crossing cover stays layer-granular and complete.
        for (_, taint) in propagate(&g).crossings {
            assert_eq!(taint, cover(&[0, 1]));
        }
    }

    #[test]
    fn reachability_follows_edges() {
        use crate::analysis::plan::test_plan;
        let plan = test_plan(2);
        let g = Graph::lower(&plan);
        let input = 0;
        let update = g.nodes.iter().position(|k| *k == NodeKind::Update).unwrap();
        assert!(g.reaches(input, update));
        assert!(!g.reaches(update, input));
    }
}
