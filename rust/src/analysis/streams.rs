//! Static enumeration of every ChaCha `(seed, stream, label)` tuple a
//! run will construct, plus the injectivity / exhaustion checks over
//! that set.
//!
//! Every random draw in this codebase goes through
//! [`crate::util::rng::ChaChaRng::from_seed_stream`], whose key is the
//! `(seed, stream, label)` tuple — so "two consumers share a keystream"
//! (the PR-1 noise-seed-collision bug class) is a *statically decidable*
//! property of the run plan: enumerate the tuples, sort, look for
//! duplicates. Labels are 8-byte purpose tags (`b"poisson\0"`,
//! `b"noisesd\0"`, ...), so a collision requires either a label reuse in
//! code or a degenerate seed derivation, both of which this pass
//! catches before a step runs.
//!
//! Unbounded index families (per-step sampler streams, per-example data
//! streams) are enumerated up to [`ENUM_CAP`] entries plus the final
//! boundary element; capping cannot mask a collision *within* one
//! family (each family is injective in its index by construction — the
//! index IS the stream word), only cross-family collisions matter, and
//! those are index-independent because labels differ per family.

use crate::coordinator::config::TrainConfig;
use crate::coordinator::sampler::SamplerChoice;
use crate::coordinator::trainer::per_step_noise_seed;
use crate::runtime::ModelMeta;

/// Max enumerated tuples per index family (the last index is always
/// appended on top, so boundary behaviour is still covered).
pub const ENUM_CAP: u64 = 4096;

/// One static use of a ChaCha stream.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct StreamUse {
    /// 8-byte purpose label baked into the key.
    pub label: [u8; 8],
    /// Seed word of the key.
    pub seed: u64,
    /// Stream word of the key.
    pub stream: u64,
    /// Human name of the consumer (for diagnostics).
    pub purpose: &'static str,
}

impl StreamUse {
    /// Build a stream use record.
    pub fn new(purpose: &'static str, seed: u64, stream: u64, label: &[u8; 8]) -> Self {
        Self { label: *label, seed, stream, purpose }
    }

    /// The key identity: collides iff another use has the same triple.
    pub fn key(&self) -> (u64, u64, [u8; 8]) {
        (self.seed, self.stream, self.label)
    }

    /// Printable label (non-ASCII bytes escaped).
    pub fn label_str(&self) -> String {
        self.label
            .iter()
            .map(|&b| {
                if b.is_ascii_graphic() {
                    (b as char).to_string()
                } else {
                    format!("\\x{b:02x}")
                }
            })
            .collect()
    }
}

/// `0..n` capped at [`ENUM_CAP`] entries, always keeping the last index.
fn capped_indices(n: u64) -> Vec<u64> {
    if n <= ENUM_CAP {
        (0..n).collect()
    } else {
        let mut v: Vec<u64> = (0..ENUM_CAP).collect();
        v.push(n - 1);
        v
    }
}

/// Enumerate every `(seed, stream, label)` tuple the configured run
/// constructs: sampler streams (per step or per epoch), the noise seed
/// derivation + per-step apply-noise streams (when `with_noise`), the
/// parameter-init stream (keyed by the *manifest* seed), the synthetic
/// dataset's class/example streams, and the metrics bootstrap stream.
pub fn enumerate(
    config: &TrainConfig,
    meta: &ModelMeta,
    manifest_seed: u64,
    with_noise: bool,
) -> Vec<StreamUse> {
    let mut out = Vec::new();
    let seed = config.seed;
    let n = u64::from(config.dataset_size);

    // Sampler: one stream per step (Poisson) or per epoch (shuffle).
    match config.sampler {
        SamplerChoice::Poisson => {
            for t in capped_indices(config.steps) {
                out.push(StreamUse::new("sampler.poisson", seed, t, b"poisson\0"));
            }
        }
        SamplerChoice::Shuffle => {
            // Mirror AnySampler::from_config's batch derivation.
            let batch = ((n as f64 * config.sampling_rate).round() as u64).clamp(1, n.max(1));
            let steps_per_epoch = n.div_ceil(batch).max(1);
            let epochs = config.steps.div_ceil(steps_per_epoch).max(1);
            for e in capped_indices(epochs) {
                out.push(StreamUse::new("sampler.shuffle", seed, e, b"shuffle\0"));
            }
        }
    }

    if with_noise {
        // The derivation stream per_step_noise_seed() reads once...
        out.push(StreamUse::new("noise.derive", seed, 0, b"noisesd\0"));
        // ...and the per-step apply streams keyed by the folded seed.
        for t in capped_indices(config.steps) {
            out.push(StreamUse::new(
                "noise.apply",
                per_step_noise_seed(seed, t),
                0,
                b"applynse",
            ));
        }
    }

    // Parameter init: keyed by the manifest seed, not the run seed.
    out.push(StreamUse::new("init.params", manifest_seed, 0, b"refinit\0"));

    // Synthetic data: class patterns + per-example streams. Train and
    // held-out sets share these tuples BY DESIGN (same underlying
    // distribution), so enumerate the union once.
    for c in capped_indices(meta.num_classes as u64) {
        out.push(StreamUse::new("data.class", seed, c, b"classpat"));
    }
    let examples = n + u64::from(config.eval_examples);
    for i in capped_indices(examples) {
        out.push(StreamUse::new("data.example", seed, i, b"example\0"));
    }

    // Metrics bootstrap CIs.
    out.push(StreamUse::new("metrics.bootstrap", seed, 0, b"bootstrp"));

    out
}

/// All pairs of distinct uses sharing one `(seed, stream, label)` key.
pub fn find_collisions(streams: &[StreamUse]) -> Vec<(StreamUse, StreamUse)> {
    let mut sorted: Vec<&StreamUse> = streams.iter().collect();
    sorted.sort_by_key(|s| s.key());
    sorted
        .windows(2)
        .filter(|w| w[0].key() == w[1].key())
        .map(|w| (w[0].clone(), w[1].clone()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capped_enumeration_keeps_the_boundary() {
        assert_eq!(capped_indices(3), vec![0, 1, 2]);
        let big = capped_indices(1 << 40);
        assert_eq!(big.len() as u64, ENUM_CAP + 1);
        assert_eq!(*big.last().unwrap(), (1 << 40) - 1);
        // No duplicate introduced by the cap (last > cap range).
        assert!(big.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn collisions_found_regardless_of_order() {
        let a = StreamUse::new("x", 1, 2, b"labelone");
        let b = StreamUse::new("y", 1, 2, b"labelone");
        let c = StreamUse::new("z", 1, 3, b"labelone");
        assert!(find_collisions(&[a.clone(), c.clone()]).is_empty());
        let hits = find_collisions(&[c, a.clone(), b]);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].0.key(), a.key());
    }

    #[test]
    fn label_str_escapes_non_ascii() {
        let s = StreamUse::new("x", 0, 0, b"poisson\0");
        assert_eq!(s.label_str(), "poisson\\x00");
    }
}
