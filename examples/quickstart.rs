//! Quickstart: train with DP-SGD **without shortcuts** — exact Poisson
//! subsampling, Algorithm-2 masked virtual batching, RDP accounting —
//! then evaluate, all through the public API.
//!
//! ```bash
//! cargo run --release --example quickstart            # reference backend
//! make artifacts && cargo run --release --features pjrt --example quickstart
//! ```

use dp_shortcuts::coordinator::config::TrainConfig;
use dp_shortcuts::coordinator::trainer::Trainer;
use dp_shortcuts::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. Pick a runtime: the AOT artifacts when present (built once by
    //    `make artifacts`; Python is never on this path), otherwise the
    //    pure-Rust reference backend so the quickstart always runs.
    let rt = Runtime::auto("artifacts")?;
    let model = rt.default_model().expect("model").to_string();
    println!("backend: {} / model: {model}", rt.backend_name());

    // 2. Configure a run. Defaults mirror the paper's setup (sampling
    //    rate 0.5, eps=8, delta=2.04e-5); we shrink the dataset so the
    //    quickstart finishes in seconds on one CPU core.
    let cfg = TrainConfig {
        model,
        variant: "masked".into(), // Algorithm 2: fixed shapes + masks
        dataset_size: 512,
        sampling_rate: 0.25, // E[L] = 128
        physical_batch: 16,
        steps: 8,
        lr: 3.0e-4,
        eval_examples: 128,
        ..Default::default()
    };

    // 3. Train. The trainer Poisson-samples each logical batch, splits
    //    it into masked physical batches, accumulates clipped gradients
    //    through the backend's executables, and takes one noisy step per
    //    logical batch.
    let trainer = Trainer::new(&rt, cfg)?;
    let report = trainer.run()?;

    println!("== quickstart: DP-SGD without shortcuts ==");
    println!(
        "privacy: sigma = {:.4}, spent (eps = {:.3}, delta = {:.1e})",
        report.noise_multiplier, report.epsilon_spent, report.delta
    );
    for s in &report.steps {
        println!(
            "step {:>2}: sampled |L| = {:<4} -> {} physical batches, loss {:.4}",
            s.step, s.logical_batch, s.physical_batches, s.loss
        );
    }
    println!(
        "throughput: {:.1} examples/s (+{:.0}% computed as Alg.2 padding)",
        report.throughput,
        100.0 * (report.computed_throughput / report.throughput - 1.0)
    );
    if let (Some(l), Some(a)) = (report.eval_loss, report.eval_accuracy) {
        println!("held-out: loss {:.4}, accuracy {:.3}", l, a);
    }
    Ok(())
}
