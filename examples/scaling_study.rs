//! Multi-GPU scaling study (paper Section 7, Figures 7 / A.4 / A.5):
//! measures real single-worker throughput of the private and non-private
//! executables, simulates data-parallel scaling over a 4-GPU-per-node
//! cluster with hierarchical ring all-reduce — and, when a
//! `BENCH_throughput.json` (schema v3, `dpshort bench --workers`) is
//! present, overlays the *measured* data-parallel worker curves from
//! the real multi-session executor (DESIGN.md §8) — one series per
//! (model, clip method) — against the simulation.
//!
//! ```bash
//! cargo run --release --example scaling_study -- [model] [gpus,...] [bench.json]
//! # measured overlay appears automatically if ./BENCH_throughput.json exists:
//! cargo run --release --bin dpshort -- bench --quick --workers 1,2,4
//! cargo run --release --example scaling_study
//! ```

use dp_shortcuts::benchreport::BenchReport;
use dp_shortcuts::cluster::fit_parallel_fraction;
use dp_shortcuts::report::print_scaling_study;
use dp_shortcuts::runtime::Runtime;
use std::path::Path;

/// Print the measured data-parallel curves from a bench file, if one
/// exists and carries them — one series per (model, clip method) in a
/// schema-v3 file; v2 files hold a single unkeyed series. Returns
/// whether the overlay (or its file-specific guidance) was printed —
/// `false` only when no bench file exists at all, so the caller prints
/// exactly one fallback line.
fn print_measured_overlay(path: &Path) -> anyhow::Result<bool> {
    if !path.exists() {
        return Ok(false);
    }
    // Validated load: a corrupt or schema-violating file is reported,
    // not silently plotted.
    let report = BenchReport::check_file(path)?;
    let Some(curve) = &report.workers else {
        println!(
            "\n(measured overlay: {} is schema v{} without a `workers` curve — \
             re-run `dpshort bench --workers 1,2,4` to record one)",
            path.display(),
            report.schema_version
        );
        return Ok(true);
    };
    // Series in first-appearance order; v2 files yield exactly one
    // (their rows carry an empty clip_method).
    let mut series: Vec<(&str, &str)> = Vec::new();
    for w in curve {
        let key = (w.model.as_str(), w.clip_method.as_str());
        if !series.contains(&key) {
            series.push(key);
        }
    }
    let mut printed_any = false;
    for (model, method) in series {
        let rows: Vec<_> = curve
            .iter()
            .filter(|w| w.model == model && w.clip_method == method)
            .collect();
        let Some(base) = rows.iter().find(|w| w.workers == 1) else {
            println!(
                "\n(measured overlay: {model}/{method} has no 1-worker baseline row — \
                 add 1 to the bench --workers list for speedup normalization)"
            );
            printed_any = true;
            continue;
        };
        let label = if method.is_empty() { "(v2 file)" } else { method };
        println!(
            "\n== measured data-parallel scaling ({}, backend {}, model {model}, clip {label}) ==",
            path.display(),
            report.backend,
        );
        println!(
            "  {:>7} {:>12} {:>9} {:>7}",
            "workers", "ex/s (wall)", "speedup", "eff"
        );
        let mut points = Vec::new();
        for w in &rows {
            let speedup = w.throughput / base.throughput;
            println!(
                "  {:>7} {:>12.1} {:>8.2}x {:>6.1}%",
                w.workers,
                w.throughput,
                speedup,
                100.0 * speedup / w.workers as f64
            );
            if w.workers > 1 {
                points.push((w.workers as f64, speedup));
            }
        }
        if !points.is_empty() {
            let frac = fit_parallel_fraction(&points);
            println!(
                "  Amdahl parallel fraction (measured): {:.2}% \
                 (paper: private 99.5%, non-private 98.9%)",
                frac * 100.0
            );
        }
        printed_any = true;
    }
    if printed_any {
        println!(
            "  NOTE: reference-backend workers share one CPU, so measured efficiency\n\
             \x20 sits below the simulated multi-GPU curve; compare the *shape* (the\n\
             \x20 Amdahl fraction), as the paper's Figure 7 does."
        );
    }
    Ok(true)
}

fn main() -> anyhow::Result<()> {
    let gpus: Vec<usize> = std::env::args()
        .nth(2)
        .map(|s| s.split(',').map(|x| x.parse().expect("gpu count")).collect())
        .unwrap_or_else(|| vec![1, 2, 4, 8, 16, 32, 64, 80]);
    // Artifacts + PJRT when available, pure-Rust reference otherwise.
    let rt = Runtime::auto("artifacts")?;
    let model = std::env::args()
        .nth(1)
        .unwrap_or_else(|| rt.default_model().expect("model").to_string());
    print_scaling_study(&rt, &model, &gpus)?;

    // Measured overlay: explicit path, or the default bench output if
    // it exists in the working directory (graceful fallback to pure
    // simulation otherwise).
    let bench_path = std::env::args()
        .nth(3)
        .unwrap_or_else(|| dp_shortcuts::benchreport::DEFAULT_OUT.to_string());
    let overlaid = print_measured_overlay(Path::new(&bench_path))?;
    if !overlaid {
        println!(
            "\n(no measured worker curve at {bench_path}; simulation only — \
             run `dpshort bench --workers 1,2,4` first for the overlay)"
        );
    }

    println!("\nInterpretation: the private step computes ~Nx longer per example,");
    println!("so the fixed-size gradient all-reduce is a smaller fraction of each");
    println!("step and the inter-node fabric saturates later — the paper's");
    println!("'DP-SGD scales better than SGD' result.");
    Ok(())
}
