//! Multi-GPU scaling study (paper Section 7, Figures 7 / A.4 / A.5):
//! measures real single-worker throughput of the private and non-private
//! executables, then simulates data-parallel scaling over a 4-GPU-per-
//! node cluster with hierarchical ring all-reduce.
//!
//! ```bash
//! cargo run --release --example scaling_study -- [model] [gpus,...]
//! ```

use dp_shortcuts::report::print_scaling_study;
use dp_shortcuts::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let gpus: Vec<usize> = std::env::args()
        .nth(2)
        .map(|s| s.split(',').map(|x| x.parse().expect("gpu count")).collect())
        .unwrap_or_else(|| vec![1, 2, 4, 8, 16, 32, 64, 80]);
    // Artifacts + PJRT when available, pure-Rust reference otherwise.
    let rt = Runtime::auto("artifacts")?;
    let model = std::env::args()
        .nth(1)
        .unwrap_or_else(|| rt.default_model().expect("model").to_string());
    print_scaling_study(&rt, &model, &gpus)?;
    println!("\nInterpretation: the private step computes ~Nx longer per example,");
    println!("so the fixed-size gradient all-reduce is a smaller fraction of each");
    println!("step and the inter-node fabric saturates later — the paper's");
    println!("'DP-SGD scales better than SGD' result.");
    Ok(())
}
