//! Fine-tuning scenario (the paper's workload): full-parameter DP
//! fine-tuning with paper hyperparameters, comparing every clipping
//! method available for the model — the Figure 1/4 experience as a
//! program.
//!
//! ```bash
//! cargo run --release --example dp_finetune -- [model]
//! ```

use dp_shortcuts::coordinator::config::TrainConfig;
use dp_shortcuts::coordinator::trainer::Trainer;
use dp_shortcuts::metrics::summary_with_ci;
use dp_shortcuts::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // Artifacts + PJRT when available, pure-Rust reference otherwise.
    let rt = Runtime::auto("artifacts")?;
    let model = std::env::args()
        .nth(1)
        .unwrap_or_else(|| rt.default_model().expect("model").to_string());
    let meta = rt.manifest().model(&model)?.clone();

    println!("== DP fine-tuning study: {model} ({} params) ==", meta.n_params);
    println!(
        "{:<12} {:>8} {:>12} {:>10} {:>10} {:>8}",
        "variant", "B", "ex/s (CI)", "rel", "eps", "acc"
    );

    // Non-private first so the relative column is anchored to it.
    let mut variants = meta.variants();
    variants.sort_by_key(|v| (v != "nonprivate", v.clone()));
    let batch = *meta
        .accum_batches("nonprivate", "f32")
        .last()
        .expect("nonprivate artifacts");

    let mut base: Option<f64> = None;
    for variant in &variants {
        if variant == "naive" {
            continue; // same graph as masked; its story is recompilation (Fig A.2)
        }
        if !meta.accum_batches(variant, "f32").contains(&batch) {
            continue;
        }
        let cfg = TrainConfig {
            model: model.clone(),
            variant: variant.clone(),
            dataset_size: 512,
            sampling_rate: 0.5, // the paper's q
            physical_batch: batch,
            steps: 4, // the paper's benchmark length
            eval_examples: 64,
            ..Default::default()
        };
        let trainer = Trainer::new(&rt, cfg)?;
        // Steady-state throughput with CIs (Fig 6 methodology)...
        let samples = trainer.bench_accum(variant, batch, 6)?;
        let s = summary_with_ci(&samples, 0);
        // ...and a real training run for the privacy/accuracy columns.
        let rep = trainer.run()?;
        let baseline = *base.get_or_insert(s.median);
        println!(
            "{:<12} {:>8} {:>7.1} ±{:>4.0} {:>10.2} {:>10.3} {:>8.3}",
            variant,
            batch,
            s.median,
            (s.ci_high - s.ci_low) / 2.0,
            s.median / baseline,
            rep.epsilon_spent,
            rep.eval_accuracy.unwrap_or(f64::NAN),
        );
    }
    println!("\n(paper Fig 1: ghost/BK recover about half of the DP slowdown;");
    println!(" per-example (masked graph) costs x2.6-3.2 for ViTs)");
    Ok(())
}
