//! Memory planner (paper Figure 3 / Table 3): given a VRAM budget, what
//! is the maximum physical batch size per model and clipping method —
//! and which models cannot fit even one example under per-example
//! clipping (the regime where ghost clipping is mandatory).
//!
//! ```bash
//! cargo run --release --example max_batch_planner -- [budget-gb]
//! ```

use dp_shortcuts::clipping::ClippingMethod;
use dp_shortcuts::memory::MemModel;
use dp_shortcuts::models::paper_ladder;
use dp_shortcuts::report::print_max_batch_table;

fn main() {
    let budget_gb: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40.0);
    print_max_batch_table(budget_gb * 1e9);

    // Planner mode: the largest model trainable at all, per method.
    println!("\n== largest trainable model at {budget_gb} GB (>= 1 example) ==");
    let mem = MemModel::default();
    for method in [
        ClippingMethod::NonPrivate,
        ClippingMethod::PerExample,
        ClippingMethod::Ghost,
        ClippingMethod::BkGhost,
    ] {
        let mut best = "(none)".to_string();
        for arch in paper_ladder() {
            if !method.supports(arch.family) {
                continue;
            }
            if mem.max_physical_batch(&arch, method, budget_gb * 1e9) >= 1 {
                best = format!("{} ({:.0}M params)", arch.name, arch.params_m());
            }
        }
        println!("  {:<26} {best}", method.label());
    }
    println!("\n(ghost-style methods keep the max batch near the non-private");
    println!(" ceiling because they never materialize [B, P] per-example grads)");
}
