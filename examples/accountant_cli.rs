//! Privacy accountant walkthrough: what the paper's hyperparameters
//! (Table A2: eps=8, delta=2.04e-5, q=0.5, T=4) actually imply, and why
//! the Poisson assumption matters.
//!
//! ```bash
//! cargo run --release --example accountant_cli
//! ```

use dp_shortcuts::privacy::rdp::StreamingAccountant;
use dp_shortcuts::privacy::{calibrate_sigma, RdpAccountant};

fn main() {
    let (eps, delta, q, steps) = (8.0, 2.04e-5, 0.5, 4u64);
    println!("== the paper's privacy budget (Table A2, ViT) ==");
    println!("target: (eps={eps}, delta={delta:.2e}) with q={q}, T={steps}");

    let sigma = calibrate_sigma(eps, delta, q, steps).expect("calibration");
    println!("calibrated noise multiplier: sigma = {sigma:.4}");

    let acc = RdpAccountant::default();
    println!("\nper-step spend (streaming accountant):");
    let mut s = StreamingAccountant::new(acc.clone());
    for t in 0..steps {
        s.record_step(q, sigma);
        println!("  after step {}: eps = {:.4}", t + 1, s.epsilon(delta));
    }

    println!("\nsensitivity of the budget to the subsampling assumption:");
    println!("(what the accountant *claims* if the code silently uses a");
    println!(" different effective rate than the accounted q = {q})");
    for q_eff in [0.25, 0.5, 0.75, 1.0] {
        let e = acc.epsilon(q_eff, sigma, steps, delta);
        println!("  effective q = {q_eff:<5} -> eps = {e:.3}");
    }
    println!("\nShuffle-and-fixed-batch sampling has NO valid q for this");
    println!("accountant (Lebeda et al. 2024) — which is why this codebase");
    println!("implements true Poisson subsampling (the paper's point).");

    println!("\nlonger training at the same budget:");
    for t in [4u64, 40, 400, 4000] {
        let sig = calibrate_sigma(eps, delta, q, t).expect("calibration");
        println!("  T = {t:<5} -> sigma = {sig:.3}");
    }
}
