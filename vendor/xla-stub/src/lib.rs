//! Offline stub of the `xla` PJRT bindings.
//!
//! The real bindings (PJRT C API over the AOT-lowered HLO artifacts)
//! are unavailable in the offline build environment. This stub mirrors
//! exactly the API surface `dp-shortcuts`' `pjrt` backend uses, so
//! `cargo check --features pjrt` type-checks the whole PJRT path; every
//! runtime entry point returns [`Error::Unavailable`] instead of
//! executing. Swap the `[dependencies.xla]` path in the root manifest
//! for real bindings to run artifacts for real.

use std::path::Path;

/// Error type matching the bindings' `xla::Error` role.
#[derive(Debug)]
pub enum Error {
    /// The stub was called at runtime: no PJRT plugin is linked in.
    Unavailable(&'static str),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Unavailable(what) => {
                write!(f, "xla stub: {what} requires the real PJRT bindings")
            }
        }
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &'static str) -> Result<T, Error> {
    Err(Error::Unavailable(what))
}

/// Element types the bindings marshal across the PJRT boundary.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side literal (stub: carries no data).
#[derive(Debug, Default, Clone)]
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Self {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T, Error> {
        unavailable("Literal::get_first_element")
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal), Error> {
        unavailable("Literal::to_tuple2")
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal), Error> {
        unavailable("Literal::to_tuple3")
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<Self, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Compilable computation (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Device buffer returned by an execution (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Loaded executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute(&self, _args: &[&Literal]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client (stub). `cpu()` fails, so a `pjrt`-feature build reports
/// a clear error the moment a runtime is constructed.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}
