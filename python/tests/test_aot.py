"""AOT pipeline tests: lowering produces parseable HLO text with the
declared ABI, and the manifest matches what was written.

These run the same code path as `make artifacts` on the smallest model
only (fast), into a temp dir.
"""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.model import ModelBundle


@pytest.fixture(scope="module")
def lowered(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    plan = {
        "variants": ["nonprivate", "masked"],
        "batches": [2],
        "bf16": None,
        "eval_batch": 2,
    }
    entry = aot.lower_model("vit-micro", plan, out, seed=0)
    return out, entry


def test_artifacts_written(lowered):
    out, entry = lowered
    assert (out / "vit-micro_init.bin").exists()
    paths = {e["path"] for e in entry["executables"]}
    assert "vit-micro_apply.hlo.txt" in paths
    assert "vit-micro_eval_B2.hlo.txt" in paths
    assert "vit-micro_masked_B2_accum.hlo.txt" in paths
    for p in paths:
        text = (out / p).read_text()
        assert text.startswith("HloModule"), p


def test_init_params_byte_count(lowered):
    out, entry = lowered
    n = entry["n_params"]
    assert (out / "vit-micro_init.bin").stat().st_size == 4 * n
    # and round-trips to the in-memory initialization
    mb = ModelBundle("vit-micro", seed=0)
    disk = np.fromfile(out / "vit-micro_init.bin", dtype=np.float32)
    np.testing.assert_array_equal(disk, np.asarray(mb.params_flat))


def test_hlo_entry_layout_matches_abi(lowered):
    """The accum entry computation must be
    (params[P], acc[P], x[B,H,W,C], y[B], mask[B]) -> 3-tuple."""
    out, entry = lowered
    p = entry["n_params"]
    text = (out / "vit-micro_masked_B2_accum.hlo.txt").read_text()
    header = text.splitlines()[0]
    assert f"f32[{p}]" in header
    assert "f32[2,32,32,3]" in header
    assert "s32[2]" in header
    # 3-tuple result: (acc, loss, sq_norms)
    assert f"(f32[{p}]" in header.split("->")[1]


def test_flops_estimate_positive(lowered):
    _, entry = lowered
    assert entry["flops_fwd_per_example"] > 1e5


def test_manifest_roundtrip(tmp_path):
    m = {"version": 1, "seed": 0, "models": {}}
    path = tmp_path / "manifest.json"
    path.write_text(json.dumps(m))
    assert json.loads(path.read_text()) == m


def test_hlo_has_no_custom_calls(lowered):
    """interpret=True Pallas must lower to plain HLO ops — a Mosaic
    custom-call would be unloadable by the CPU PJRT client."""
    out, entry = lowered
    for e in entry["executables"]:
        text = (out / e["path"]).read_text()
        assert "custom-call" not in text or "Sharding" in text, e["path"]
