"""L2 correctness: model shapes, gradient consistency across the five
step variants, and the Algorithm-2 equivalences the paper's privacy
argument rests on.

Key theorems tested:

* masked(batch, mask) == naive(subset)     — Algorithm 2 == Algorithm 1
* ghost == bk == masked gradients + norms  — all clipping paths agree
* per-example clipped contributions respect ||g_i|| <= C
* bf16 variant approximates f32 (the TF32 substitute)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import ModelBundle
from compile import vit, resnet

B = 4
C = 1.0


def data(mb, b=B, seed=0):
    rng = np.random.default_rng(seed)
    cfg = mb.cfg
    x = jnp.asarray(rng.normal(size=(b, cfg.image, cfg.image, cfg.channels)), jnp.float32)
    y = jnp.asarray(rng.integers(0, cfg.num_classes, size=(b,)), jnp.int32)
    return x, y


@pytest.fixture(scope="module")
def vit_micro():
    return ModelBundle("vit-micro")


@pytest.fixture(scope="module")
def rn_micro():
    return ModelBundle("rn-micro")


# ------------------------------------------------------------ shapes / init

def test_vit_ladder_configs_monotone():
    sizes = [ModelBundle(n).n_params for n in ["vit-micro", "vit-tiny"]]
    assert sizes[0] < sizes[1]


def test_vit_forward_shapes(vit_micro):
    x, y = data(vit_micro)
    params = vit_micro.params
    logits, acts = vit.vit_single(
        vit_micro.cfg, params["lin"], params["oth"], x[0], None, True
    )
    assert logits.shape == (vit_micro.cfg.num_classes,)
    assert set(acts) == set(vit_micro.cfg.linear_shapes())


def test_resnet_forward_shapes(rn_micro):
    x, y = data(rn_micro)
    params = rn_micro.params
    logits, _ = resnet.resnet_single(rn_micro.cfg, params["lin"], params["oth"], x[0])
    assert logits.shape == (rn_micro.cfg.num_classes,)


def test_flat_param_roundtrip(vit_micro):
    tree = vit_micro.unravel(vit_micro.params_flat)
    flat2, _ = jax.flatten_util.ravel_pytree(tree)
    np.testing.assert_array_equal(vit_micro.params_flat, flat2)


# --------------------------------------------- Algorithm 2 == Algorithm 1

def test_masked_equals_naive_on_subset(vit_micro):
    """THE Algorithm-2 property: processing a padded full batch with
    masks gives bit-for-bit (up to float assoc.) the same accumulated
    clipped gradient as processing just the real examples."""
    mb = vit_micro
    x, y = data(mb, b=6, seed=1)
    acc0 = jnp.zeros((mb.n_params,), jnp.float32)
    accum = jax.jit(mb.make_accum("masked", C))
    # full batch of 6 with last 2 masked out
    mask = jnp.asarray([1, 1, 1, 1, 0, 0], jnp.float32)
    acc_m, loss_m, _ = accum(mb.params_flat, acc0, x, y, mask)
    # the "naive" path: just the 4 real examples
    accum4 = jax.jit(mb.make_accum("naive", C))
    acc_n, loss_n, _ = accum4(mb.params_flat, acc0, x[:4], y[:4], jnp.ones(4))
    np.testing.assert_allclose(acc_m, acc_n, rtol=2e-4, atol=2e-6)
    np.testing.assert_allclose(loss_m, loss_n, rtol=1e-5)


def test_all_masked_batch_contributes_nothing(vit_micro):
    mb = vit_micro
    x, y = data(mb, seed=2)
    acc0 = jnp.asarray(np.random.default_rng(0).normal(size=mb.n_params), jnp.float32)
    accum = jax.jit(mb.make_accum("masked", C))
    acc, loss, _ = accum(mb.params_flat, acc0, x, y, jnp.zeros(B))
    np.testing.assert_allclose(acc, acc0, rtol=1e-6, atol=1e-6)
    assert float(loss) == 0.0


# ------------------------------------------ clipping-path equivalences

def test_ghost_and_bk_match_perexample(vit_micro):
    mb = vit_micro
    x, y = data(mb, seed=3)
    mask = jnp.asarray([1, 1, 0, 1], jnp.float32)
    acc0 = jnp.zeros((mb.n_params,), jnp.float32)
    outs = {}
    for v in ["masked", "ghost", "bk"]:
        acc, loss, sq = jax.jit(mb.make_accum(v, C))(mb.params_flat, acc0, x, y, mask)
        outs[v] = (np.asarray(acc), float(loss), np.asarray(sq))
    for v in ["ghost", "bk"]:
        np.testing.assert_allclose(outs[v][2], outs["masked"][2], rtol=5e-3)
        np.testing.assert_allclose(outs[v][0], outs["masked"][0], rtol=5e-3, atol=5e-5)
        assert abs(outs[v][1] - outs["masked"][1]) < 1e-3


def test_ghost_rejected_for_resnet(rn_micro):
    """Paper Table A1: ghost/BK do not support weight-standardized convs."""
    with pytest.raises(ValueError, match="unsupported"):
        rn_micro.make_accum("ghost", C)
    with pytest.raises(ValueError, match="unsupported"):
        rn_micro.make_accum("bk", C)


def test_clipped_contribution_bounded(vit_micro):
    """Sensitivity: each example's accumulated contribution <= C."""
    mb = vit_micro
    x, y = data(mb, b=1, seed=4)
    acc0 = jnp.zeros((mb.n_params,), jnp.float32)
    accum = jax.jit(mb.make_accum("masked", 0.05))
    acc, _, sq = accum(mb.params_flat, acc0, x, y, jnp.ones(1))
    assert float(jnp.linalg.norm(acc)) <= 0.05 * 1.001
    assert float(sq[0]) > 0.05**2  # the raw grad was genuinely clipped


def test_nonprivate_matches_unclipped_sum(vit_micro):
    """With a huge clip norm, DP-SGD accumulate == plain summed grads."""
    mb = vit_micro
    x, y = data(mb, seed=5)
    acc0 = jnp.zeros((mb.n_params,), jnp.float32)
    acc_np, _, _ = jax.jit(mb.make_accum("nonprivate", C))(
        mb.params_flat, acc0, x, y, jnp.ones(B)
    )
    huge = jax.jit(mb.make_accum("masked", 1e9))
    acc_pe, _, _ = huge(mb.params_flat, acc0, x, y, jnp.ones(B))
    np.testing.assert_allclose(acc_pe, acc_np, rtol=2e-3, atol=2e-4)


# ----------------------------------------------------------- apply / eval

def test_apply_deterministic_per_seed(vit_micro):
    mb = vit_micro
    acc = jnp.asarray(np.random.default_rng(1).normal(size=mb.n_params), jnp.float32)
    one = lambda s: jax.jit(mb.apply_fn)(
        mb.params_flat,
        acc,
        jnp.asarray([s], jnp.int32),
        jnp.asarray([100.0], jnp.float32),
        jnp.asarray([0.1], jnp.float32),
        jnp.asarray([1.0], jnp.float32),
    )
    np.testing.assert_array_equal(one(7), one(7))
    assert not np.array_equal(np.asarray(one(7)), np.asarray(one(8)))


def test_apply_noise_has_right_scale(vit_micro):
    """params' - sgd_step == -lr * noise_mult/denom * N(0,1): check std."""
    mb = vit_micro
    acc = jnp.zeros((mb.n_params,), jnp.float32)
    lr, denom, nm = 1.0, 1.0, 3.0
    out = jax.jit(mb.apply_fn)(
        mb.params_flat,
        acc,
        jnp.asarray([123], jnp.int32),
        jnp.asarray([denom], jnp.float32),
        jnp.asarray([lr], jnp.float32),
        jnp.asarray([nm], jnp.float32),
    )
    diff = np.asarray(out - mb.params_flat)
    assert abs(diff.std() - nm) / nm < 0.02
    assert abs(diff.mean()) < 0.05


def test_eval_counts_correct(vit_micro):
    mb = vit_micro
    x, y = data(mb, seed=6)
    loss_sum, ncorrect = jax.jit(mb.eval_fn)(mb.params_flat, x, y)
    assert 0 <= float(ncorrect) <= B
    assert float(loss_sum) > 0


# ------------------------------------------------------------------- bf16

def test_bf16_variant_approximates_f32():
    mb32 = ModelBundle("vit-micro", dtype=jnp.float32)
    mb16 = ModelBundle("vit-micro", dtype=jnp.bfloat16)
    x, y = data(mb32, seed=7)
    acc0 = jnp.zeros((mb32.n_params,), jnp.float32)
    mask = jnp.ones(B)
    a32, l32, _ = jax.jit(mb32.make_accum("masked", C))(mb32.params_flat, acc0, x, y, mask)
    a16, l16, _ = jax.jit(mb16.make_accum("masked", C))(mb16.params_flat, acc0, x, y, mask)
    # bf16 matmuls: loose tolerance, but must be strongly correlated
    corr = np.corrcoef(np.asarray(a32), np.asarray(a16))[0, 1]
    assert corr > 0.98, corr
    assert abs(float(l16) - float(l32)) / float(l32) < 0.05


# -------------------------------------------------------- loss sanity

def test_one_sgd_step_reduces_loss(vit_micro):
    """A single non-private step on one batch must reduce that batch's
    loss (learnability smoke test for the whole fwd/bwd)."""
    mb = vit_micro
    x, y = data(mb, b=8, seed=8)
    mask = jnp.ones(8)
    acc0 = jnp.zeros((mb.n_params,), jnp.float32)
    accum = jax.jit(mb.make_accum("nonprivate", C))
    acc, loss0, _ = accum(mb.params_flat, acc0, x, y, mask)
    new_params = jax.jit(mb.apply_fn)(
        mb.params_flat,
        acc,
        jnp.asarray([0], jnp.int32),
        jnp.asarray([8.0], jnp.float32),
        jnp.asarray([0.05], jnp.float32),
        jnp.asarray([0.0], jnp.float32),
    )
    _, loss1, _ = accum(new_params, acc0, x, y, mask)
    assert float(loss1) < float(loss0), (float(loss0), float(loss1))
