"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and dtypes (the CORE correctness signal for the
kernels that end up inside the AOT-lowered step graphs).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    clip_accum,
    ghost_sq_norm,
    per_example_sq_norms,
    noisy_step,
    ref,
)

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")

DTYPES = [jnp.float32, jnp.bfloat16]


def rand(rng, shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------- grad_norm

@given(
    b=st.integers(1, 9),
    p=st.integers(1, 5000),
    dtype=st.sampled_from(DTYPES),
    seed=st.integers(0, 2**31 - 1),
)
def test_sq_norms_match_ref(b, p, dtype, seed):
    rng = np.random.default_rng(seed)
    g = rand(rng, (b, p), dtype)
    got = per_example_sq_norms(g)
    want = ref.per_example_sq_norms(g)
    np.testing.assert_allclose(got, want, rtol=2e-3 if dtype == jnp.bfloat16 else 1e-5)


def test_sq_norms_zero_and_huge_rows():
    g = jnp.zeros((3, 100), jnp.float32)
    np.testing.assert_allclose(per_example_sq_norms(g), np.zeros(3))
    g = jnp.full((2, 10), 1e3, jnp.float32)
    np.testing.assert_allclose(per_example_sq_norms(g), np.full(2, 1e7), rtol=1e-6)


# --------------------------------------------------------------- clip_accum

@given(
    b=st.integers(1, 8),
    p=st.integers(1, 4097),
    clip=st.floats(0.1, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_clip_accum_matches_ref(b, p, clip, seed):
    rng = np.random.default_rng(seed)
    g = rand(rng, (b, p))
    acc = rand(rng, (p,))
    mask = jnp.asarray(rng.integers(0, 2, size=b), jnp.float32)
    got_acc, got_sq = clip_accum(acc, g, mask, clip)
    want_acc, want_sq = ref.clip_accum(acc, g, mask, clip)
    np.testing.assert_allclose(got_sq, want_sq, rtol=1e-4)
    np.testing.assert_allclose(got_acc, want_acc, rtol=1e-4, atol=1e-5)


def test_clip_accum_respects_clip_bound():
    """Each example's contribution has norm <= C (the DP sensitivity)."""
    rng = np.random.default_rng(0)
    p, clip = 257, 0.5
    for scale in [0.01, 1.0, 100.0]:
        g = rand(rng, (1, p), scale=scale)
        acc0 = jnp.zeros((p,))
        acc, _ = clip_accum(acc0, g, jnp.ones(1), clip)
        norm = float(jnp.linalg.norm(acc))
        assert norm <= clip * 1.001, f"scale={scale}: {norm}"


def test_clip_accum_mask_zeroes_contribution():
    rng = np.random.default_rng(1)
    g = rand(rng, (4, 100))
    acc0 = jnp.zeros((100,))
    acc_all, _ = clip_accum(acc0, g, jnp.asarray([1.0, 0.0, 0.0, 0.0]), 1.0)
    acc_one, _ = clip_accum(acc0, g[:1], jnp.ones(1), 1.0)
    np.testing.assert_allclose(acc_all, acc_one, rtol=1e-5, atol=1e-6)


def test_clip_accum_small_grads_pass_through():
    """Norms below C must not be scaled (factor = 1, not C/||g||)."""
    rng = np.random.default_rng(2)
    g = rand(rng, (2, 50), scale=1e-3)
    acc0 = jnp.zeros((50,))
    acc, _ = clip_accum(acc0, g, jnp.ones(2), 10.0)
    np.testing.assert_allclose(acc, jnp.sum(g, 0), rtol=1e-5, atol=1e-7)


# --------------------------------------------------------------- ghost_norm

@given(
    b=st.integers(1, 6),
    t=st.integers(1, 17),
    d_in=st.integers(1, 33),
    d_out=st.integers(1, 29),
    seed=st.integers(0, 2**31 - 1),
)
def test_ghost_norm_matches_ref_and_direct(b, t, d_in, d_out, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, (b, t, d_in))
    bb = rand(rng, (b, t, d_out))
    got = ghost_sq_norm(a, bb)
    np.testing.assert_allclose(got, ref.ghost_sq_norm(a, bb), rtol=1e-4)
    # and against the materialized per-example grads
    np.testing.assert_allclose(got, ref.ghost_sq_norm_direct(a, bb), rtol=1e-3)


def test_ghost_norm_rank_one_identity():
    """t=1: ||a^T b||_F^2 = ||a||^2 ||b||^2 exactly."""
    rng = np.random.default_rng(3)
    a = rand(rng, (5, 1, 7))
    b = rand(rng, (5, 1, 11))
    want = np.sum(np.asarray(a) ** 2, (1, 2)) * np.sum(np.asarray(b) ** 2, (1, 2))
    np.testing.assert_allclose(ghost_sq_norm(a, b), want, rtol=1e-4)


# --------------------------------------------------------------- noisy_step

@given(
    p=st.integers(1, 5000),
    denom=st.floats(1.0, 1e5),
    lr=st.floats(1e-5, 1.0),
    nm=st.floats(0.0, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_noisy_step_matches_ref(p, denom, lr, nm, seed):
    rng = np.random.default_rng(seed)
    params = rand(rng, (p,))
    acc = rand(rng, (p,))
    noise = rand(rng, (p,))
    got = noisy_step(params, acc, noise, denom, lr, nm)
    want = ref.noisy_step(params, acc, noise, denom, lr, nm)
    # f32 associativity differs between the fused kernel and the jnp
    # reference (mul-by-reciprocal vs divide); allow a few ulps.
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_noisy_step_zero_noise_mult_is_sgd():
    """noise_mult=0 turns the private step into plain SGD — the same
    executable serves both baselines (DESIGN.md ABI)."""
    rng = np.random.default_rng(4)
    params = rand(rng, (100,))
    acc = rand(rng, (100,))
    noise = rand(rng, (100,), scale=100.0)  # must be fully ignored
    got = noisy_step(params, acc, noise, 10.0, 0.5, 0.0)
    want = params - 0.5 * acc / 10.0
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)


def test_kernels_jit_and_grad_composable():
    """Kernels must lower inside jit (the AOT path) without callbacks."""
    @jax.jit
    def f(g, acc, mask):
        acc2, sq = clip_accum(acc, g, mask, 1.0)
        return jnp.sum(acc2) + jnp.sum(sq)

    rng = np.random.default_rng(5)
    out = f(rand(rng, (3, 300)), rand(rng, (300,)), jnp.ones(3))
    assert np.isfinite(float(out))
