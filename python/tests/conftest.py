"""Shared fixtures: make `compile` importable and keep JAX on CPU."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

os.environ.setdefault("JAX_PLATFORMS", "cpu")
