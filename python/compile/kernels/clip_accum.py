"""Pallas kernel: fused masked clip-and-accumulate (Algorithm 2 inner loop).

Given per-example grads g[B, P], running accumulator acc[P], masks mask[B]
and clip norm C, computes in ONE kernel body:

    sq_i   = ||g_i||^2
    c_i    = mask_i * min(1, C / ||g_i||)
    acc'   = acc + sum_i c_i g_i        (a (1,B)x(B,P) MXU matvec)

Two schedules:

* [`clip_accum`] — the default **fused single-block** schedule: one grid
  step over the whole [B, P] panel, no padding. This is what the AOT
  artifacts embed. Perf iteration log (EXPERIMENTS.md §Perf-L1): the
  original two-pass, 2048-float-tiled schedule cost 165 ms/step on
  vit-micro B16 under interpret mode (the per-step grid overhead and the
  jnp.pad copies dominated); the fused no-pad schedule runs the same
  computation in 3.5 ms — *faster* than the pure-jnp reference (4.2 ms).

* [`clip_accum_tiled`] — the TPU-shaped tiled two-pass schedule (norms
  reduction over parameter tiles, then a scale-and-reduce pass), kept and
  property-tested for the real-hardware path where [B, P] exceeds VMEM
  and must stream HBM->VMEM tile by tile. interpret mode has no VMEM, so
  the CPU artifacts use the fused schedule.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .grad_norm import choose_ptile, per_example_sq_norms


def _fused_kernel(clip_ref, mask_ref, g_ref, acc_ref, o_ref, sq_ref):
    """One grid step over the whole [B, P] panel."""
    g = g_ref[...]
    sq = jnp.sum(g * g, axis=1)
    norms = jnp.sqrt(jnp.maximum(sq, 0.0))
    coef = jnp.minimum(1.0, clip_ref[0] / jnp.maximum(norms, 1e-12)) * mask_ref[...]
    o_ref[...] = acc_ref[...] + jax.lax.dot_general(
        coef, g, dimension_numbers=(((0,), (0,)), ((), ()))
    )
    sq_ref[...] = sq


@functools.partial(jax.jit, static_argnames=("clip", "interpret"))
def clip_accum(
    acc: jnp.ndarray,
    g: jnp.ndarray,
    mask: jnp.ndarray,
    clip: float,
    *,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused masked clip-and-accumulate; returns (acc', sq_norms[B]).

    Matches kernels.ref.clip_accum exactly (same epilogue arithmetic).
    """
    bsz, p = g.shape
    clip_arr = jnp.full((1,), clip, jnp.float32)
    acc_out, sq = pl.pallas_call(
        _fused_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((bsz,), lambda i: (0,)),
            pl.BlockSpec((bsz, p), lambda i: (0, 0)),
            pl.BlockSpec((p,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((p,), lambda i: (0,)),
            pl.BlockSpec((bsz,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((bsz,), jnp.float32),
        ],
        interpret=interpret,
    )(clip_arr, mask, g, acc)
    return acc_out, sq


def _scale_accum_kernel(coef_ref, g_ref, acc_ref, o_ref):
    """Tiled pass 2: o_tile = acc_tile + coef @ g_tile."""
    coef = coef_ref[...].astype(jnp.float32)
    block = g_ref[...].astype(jnp.float32)
    reduced = jax.lax.dot_general(
        coef, block, dimension_numbers=(((0,), (0,)), ((), ()))
    )
    o_ref[...] = acc_ref[...] + reduced


@functools.partial(jax.jit, static_argnames=("clip", "interpret"))
def clip_accum_tiled(
    acc: jnp.ndarray,
    g: jnp.ndarray,
    mask: jnp.ndarray,
    clip: float,
    *,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """TPU-shaped tiled schedule: VMEM-sized parameter tiles, two passes
    (norm reduction, then scale-and-reduce). Numerically identical to
    [`clip_accum`]; used on hardware where [B, P] exceeds VMEM."""
    bsz, p = g.shape
    sq = per_example_sq_norms(g, interpret=interpret)
    norms = jnp.sqrt(jnp.maximum(sq, 0.0))
    coef = jnp.minimum(1.0, clip / jnp.maximum(norms, 1e-12)) * mask

    ptile = choose_ptile(bsz, p)
    padded = pl.cdiv(p, ptile) * ptile
    g_p = jnp.pad(g, ((0, 0), (0, padded - p))) if padded != p else g
    acc_p = jnp.pad(acc, (0, padded - p)) if padded != p else acc
    grid = (padded // ptile,)
    acc_out = pl.pallas_call(
        _scale_accum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bsz,), lambda i: (0,)),
            pl.BlockSpec((bsz, ptile), lambda i: (0, i)),
            pl.BlockSpec((ptile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((ptile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        interpret=interpret,
    )(coef, g_p, acc_p)
    return acc_out[:p], sq
