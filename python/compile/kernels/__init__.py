"""Layer-1 Pallas kernels for DP-SGD hot spots, with pure-jnp oracles in ref.

All kernels are lowered with interpret=True so the AOT HLO runs on the CPU
PJRT client (Mosaic custom-calls are TPU-only); the BlockSpec schedules are
written for TPU VMEM/MXU regardless (DESIGN.md §Hardware-Adaptation).
"""

from . import ref  # noqa: F401
from .clip_accum import clip_accum  # noqa: F401
from .ghost_norm import ghost_sq_norm  # noqa: F401
from .grad_norm import per_example_sq_norms  # noqa: F401
from .noisy_step import noisy_step  # noqa: F401
