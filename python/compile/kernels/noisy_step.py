"""Pallas kernel: fused noisy SGD step (Algorithm 1/2 `Add noise` + `Step`).

    params' = params - lr * (acc + noise_mult * noise) / denom

One elementwise pass over the flat parameter vector, tiled along P so each
grid step touches a VMEM-sized block of params/acc/noise.  Fusing the four
reads + one write into a single kernel is what keeps the DP optimizer-step
overhead (paper Table 2, `OPTIMIZER STEP`: 99.65ms vs 38.17ms non-private)
down to one memory sweep; the scalars ride along as a broadcast (1,) block.

noise_mult = sigma * C; passing 0 turns this into the plain SGD step, so
the same compiled executable serves the private and non-private paths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl



def _noisy_step_kernel(scal_ref, p_ref, a_ref, n_ref, o_ref):
    denom = scal_ref[0]
    lr = scal_ref[1]
    nm = scal_ref[2]
    upd = (a_ref[...] + nm * n_ref[...]) / denom
    o_ref[...] = p_ref[...] - lr * upd


@functools.partial(jax.jit, static_argnames=("interpret",))
def noisy_step(
    params: jnp.ndarray,
    acc: jnp.ndarray,
    noise: jnp.ndarray,
    denom: jnp.ndarray,
    lr: jnp.ndarray,
    noise_mult: jnp.ndarray,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused params' = params - lr * (acc + noise_mult*noise) / denom."""
    (p,) = params.shape
    # Single-block no-pad schedule on the interpret path (see
    # clip_accum.py docstring for the perf iteration log); a real-TPU
    # deployment would tile P by the VMEM budget via choose_ptile.
    ptile = p
    padded = p
    scalars = jnp.stack(
        [
            jnp.asarray(denom, jnp.float32).reshape(()),
            jnp.asarray(lr, jnp.float32).reshape(()),
            jnp.asarray(noise_mult, jnp.float32).reshape(()),
        ]
    )
    out = pl.pallas_call(
        _noisy_step_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((ptile,), lambda i: (i,)),
            pl.BlockSpec((ptile,), lambda i: (i,)),
            pl.BlockSpec((ptile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((ptile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        interpret=interpret,
    )(scalars, params, acc, noise)
    return out
