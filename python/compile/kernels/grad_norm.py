"""Pallas kernel: per-example squared gradient norms.

Input: flattened per-example gradients g[B, P].  Output: sq[B].

TPU-shaped schedule (see DESIGN.md §Hardware-Adaptation): the reduction is
bandwidth-bound, so we tile the parameter axis into VMEM-sized blocks of
PTILE floats and run a 1-D grid over those tiles.  Every grid step loads a
(B, PTILE) block, squares and row-reduces it on the VPU, and accumulates
into the single (B,) output block (the output BlockSpec maps every grid
step to block 0, which Pallas keeps resident in VMEM across steps — the
TPU analogue of a blockwise reduction a GPU kernel would do with a
threadblock-level tree reduction).

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, and interpret mode lowers the same schedule to plain HLO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Parameter-axis tile selection. Perf iteration log (EXPERIMENTS.md
# §Perf-L1): a fixed 2048-float tile made the grid 59-400 steps long and
# interpret-mode per-step overhead dominated (165 ms/step on vit-micro
# B16, x22 over non-private). Sizing the tile to the VMEM budget
# instead — the largest block such that (B+2) rows of PTILE f32 fit in
# ~12 MiB of a TPU core's ~16 MiB VMEM — cut it to ~10 ms (x17). The
# same rule is what a production Mosaic kernel would use.
VMEM_BUDGET_FLOATS = 12 * 1024 * 1024 // 4
MAX_PTILE = 131_072


def choose_ptile(batch: int, p: int) -> int:
    """Largest parameter tile whose (batch+2) rows fit the VMEM budget."""
    by_vmem = VMEM_BUDGET_FLOATS // max(batch + 2, 1)
    tile = min(MAX_PTILE, by_vmem, max(p, 1))
    # round down to a lane-friendly multiple of 1024 (but never below)
    if tile >= 1024:
        tile -= tile % 1024
    return max(tile, 128)


def _sq_norm_kernel(g_ref, o_ref):
    """One grid step: accumulate row-wise squared sums of a (B, PTILE) block."""
    block = g_ref[...].astype(jnp.float32)
    partial = jnp.sum(block * block, axis=1)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("interpret",))
def per_example_sq_norms(g: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """Per-example squared L2 norms of g[B, P] via the tiled Pallas reduction."""
    bsz, p = g.shape
    ptile = choose_ptile(bsz, p)
    if ptile >= p:
        # Single-block fast path: no padding, one grid step.
        return pl.pallas_call(
            _sq_norm_kernel,
            grid=(1,),
            in_specs=[pl.BlockSpec((bsz, p), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((bsz,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((bsz,), jnp.float32),
            interpret=interpret,
        )(g)
    padded = pl.cdiv(p, ptile) * ptile
    if padded != p:
        # Zero-pad the parameter axis so every block is full; zeros do not
        # change the squared-norm reduction.
        g = jnp.pad(g, ((0, 0), (0, padded - p)))
    grid = (padded // ptile,)
    return pl.pallas_call(
        _sq_norm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bsz, ptile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((bsz,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((bsz,), jnp.float32),
        interpret=interpret,
    )(g)
