"""Pallas kernel: ghost-clipping per-example weight-grad norms.

For a linear layer y = a @ W with activations a[B, T, d_in] and output
grads b[B, T, d_out], the per-example weight grad is G_i = a_i^T b_i and

    ||G_i||_F^2 = <a_i a_i^T, b_i b_i^T>_F

(Li et al. 2022).  Cost O(T^2 (d_in + d_out)) per example instead of
O(T d_in d_out), and — crucially for memory, the paper's Table 3 — no
[B, d_in, d_out] per-example gradient tensor is ever materialized.

Schedule: 1-D grid over examples; each step loads one example's (T, d_in)
and (T, d_out) panels into VMEM, forms both Gram matrices on the MXU and
reduces their elementwise product on the VPU.  T is the sequence length
(tokens), so the VMEM working set is 2*T*d + 2*T^2 floats — for the model
ladder here (T <= 65, d <= 256) well under VMEM limits; a production TPU
kernel for long sequences would additionally tile T x T.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ghost_norm_kernel(a_ref, b_ref, o_ref):
    a = a_ref[0].astype(jnp.float32)  # (T, d_in)
    b = b_ref[0].astype(jnp.float32)  # (T, d_out)
    aat = jax.lax.dot_general(a, a, dimension_numbers=(((1,), (1,)), ((), ())))
    bbt = jax.lax.dot_general(b, b, dimension_numbers=(((1,), (1,)), ((), ())))
    o_ref[...] = jnp.sum(aat * bbt)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ghost_sq_norm(
    a: jnp.ndarray, b: jnp.ndarray, *, interpret: bool = True
) -> jnp.ndarray:
    """Per-example ||a_i^T b_i||_F^2 without materializing the grads."""
    bsz, t, d_in = a.shape
    _, _, d_out = b.shape
    return pl.pallas_call(
        _ghost_norm_kernel,
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, t, d_in), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, d_out), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bsz,), jnp.float32),
        interpret=interpret,
    )(a, b)
