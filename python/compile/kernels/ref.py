"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: every Pallas kernel in this
package must agree with the corresponding function here to numerical
tolerance (see python/tests/test_kernels.py, which sweeps shapes/dtypes
with hypothesis).

All functions implement pieces of Algorithm 1 / Algorithm 2 of the paper
(virtual-batching DP-SGD with Poisson subsampling and masking):

  - per-example squared gradient norms           (clip denominator)
  - clip factors  c_i = mask_i * min(1, C/||g_i||)
  - masked clip-and-accumulate                    (inner loop, Alg. 2)
  - ghost-norm  ||a_i^T b_i||_F^2 without materializing a_i^T b_i
  - noisy SGD step                                (Add noise + Step lines)
"""

from __future__ import annotations

import jax.numpy as jnp


def per_example_sq_norms(g: jnp.ndarray) -> jnp.ndarray:
    """Squared L2 norm per example of flattened per-example grads g[B, P]."""
    return jnp.sum(jnp.square(g.astype(jnp.float32)), axis=1)


def clip_factors(sq_norms: jnp.ndarray, mask: jnp.ndarray, clip: float) -> jnp.ndarray:
    """Per-example scale  c_i = mask_i * min(1, C / ||g_i||).

    This is the `Clip gradient and mask` line of Algorithm 2. A tiny eps
    guards the zero-gradient corner (the factor is then 1, not inf).
    """
    norms = jnp.sqrt(jnp.maximum(sq_norms, 0.0))
    factor = jnp.minimum(1.0, clip / jnp.maximum(norms, 1e-12))
    return factor * mask


def clip_accum(
    acc: jnp.ndarray,
    g: jnp.ndarray,
    mask: jnp.ndarray,
    clip: float,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Masked clip-and-accumulate (the physical-batch inner loop of Alg. 2).

    acc[P]   running sum of clipped grads (theta_acc)
    g[B, P]  per-example grads, flattened
    mask[B]  Alg. 2 masks (1 for sampled examples, 0 for padding)
    Returns (acc', sq_norms[B]).
    """
    sq = per_example_sq_norms(g)
    c = clip_factors(sq, mask, clip)
    acc_out = acc + jnp.einsum("b,bp->p", c, g.astype(jnp.float32))
    return acc_out, sq


def ghost_sq_norm(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Ghost-clipping squared weight-grad norms for a linear layer.

    For y = a @ W (a: [B, T, d_in], output-grad b: [B, T, d_out]) the
    per-example weight gradient is G_i = a_i^T b_i and

        ||G_i||_F^2 = sum_{t,t'} (a_i a_i^T)_{t,t'} (b_i b_i^T)_{t,t'}

    computed in O(T^2 (d_in + d_out)) instead of O(T d_in d_out)
    (Li et al. 2022; the paper's Section 2.2).
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    aat = jnp.einsum("btd,bsd->bts", a, a)
    bbt = jnp.einsum("btd,bsd->bts", b, b)
    return jnp.sum(aat * bbt, axis=(1, 2))


def ghost_sq_norm_direct(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Reference-of-the-reference: materialize G_i = a_i^T b_i and norm it."""
    g = jnp.einsum("btd,bte->bde", a.astype(jnp.float32), b.astype(jnp.float32))
    return jnp.sum(jnp.square(g), axis=(1, 2))


def bias_sq_norm(b: jnp.ndarray) -> jnp.ndarray:
    """Per-example squared norm of a bias gradient: ||sum_t b_i[t]||^2."""
    s = jnp.sum(b.astype(jnp.float32), axis=1)
    return jnp.sum(jnp.square(s), axis=-1)


def noisy_step(
    params: jnp.ndarray,
    acc: jnp.ndarray,
    noise: jnp.ndarray,
    denom: jnp.ndarray,
    lr: jnp.ndarray,
    noise_mult: jnp.ndarray,
) -> jnp.ndarray:
    """The `Add noise` + `Step` lines of Algorithm 1/2.

    params' = params - lr * (acc + noise_mult * noise) / denom

    noise is standard normal; noise_mult is sigma * C (0 => non-private
    SGD step, so the same executable serves both baselines).
    """
    return params - lr * (acc + noise_mult * noise) / denom
