"""Layer-2: DP-SGD step variants over the ViT / BiT-ResNet models.

Builds the five training-step graphs the paper benchmarks, all over a
single **flat f32 parameter vector** so the Rust coordinator (L3) never
needs to know the parameter pytree:

  nonprivate  batched-gradient SGD accumulate (the PyTorch baseline)
  naive       per-example grads -> clip -> sum (Opacus per-example; in
              JAX this is the "naive" variant that recompiles per batch
              size — we lower it at several sizes and Rust's compile
              cache measures exactly that recompilation cost, Fig. A.2)
  masked      Algorithm 2: fixed-shape physical batches + masks (the
              paper's contribution; never recompiles)
  ghost       Ghost clipping (Li et al. 2022): norms via the ghost trick,
              second backward pass with reweighted loss   [ViT only]
  bk          Book Keeping (Bu et al. 2023): one backward pass, clipped
              sums rebuilt from cached activations/output-grads [ViT only]

ABI (see DESIGN.md §3):
  accum(params[P], acc[P], x[B,H,W,C], y[B]i32, mask[B]) ->
        (acc'[P], loss_sum, sq_norms[B])
  apply(params[P], acc[P], seed i32[1], denom f32[1], lr f32[1],
        noise_mult f32[1]) -> params'[P]
  eval (params[P], x, y) -> (loss_sum, ncorrect f32)

The inner loop over physical batches calls `accum`; the once-per-logical-
batch noise+step calls `apply` (noise_mult = sigma*C; 0 = non-private).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from . import resnet, vit
from .kernels import clip_accum as k_clip_accum
from .kernels import ghost_sq_norm as k_ghost_sq_norm
from .kernels import noisy_step as k_noisy_step
from .kernels import ref as kref

GHOST_CAPABLE = ("vit",)  # paper: PV/FastDP ghost does not support BiT-ResNet


def get_model(name: str):
    """Resolve a ladder name to (cfg, single_fn, init_fn, family)."""
    if name in vit.VIT_LADDER:
        cfg = vit.VIT_LADDER[name]
        return cfg, vit.vit_single, vit.init_vit, "vit"
    if name in resnet.RESNET_LADDER:
        cfg = resnet.RESNET_LADDER[name]
        return cfg, resnet.resnet_single, resnet.init_resnet, "resnet"
    raise KeyError(f"unknown model {name!r}")


def ce_loss(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Cross-entropy of one example's logits against integer label y."""
    return jax.nn.logsumexp(logits) - logits[y]


def _flatten_batch(tree, bsz: int) -> jnp.ndarray:
    """Per-example grad tree (leaves [B, ...]) -> [B, P].

    Leaf order matches ravel_pytree(params): both use tree_flatten order.
    """
    return jnp.concatenate(
        [l.reshape(bsz, -1) for l in jax.tree_util.tree_leaves(tree)], axis=1
    )


class ModelBundle:
    """One ladder rung: params template, flat<->tree adapters, step builders."""

    def __init__(self, name: str, seed: int = 0, dtype=jnp.float32):
        cfg, single, init, family = get_model(name)
        self.name, self.cfg, self.family, self.dtype = name, cfg, family, dtype
        self._single = single
        self.params = init(jax.random.PRNGKey(seed), cfg)
        flat, unravel = ravel_pytree(self.params)
        self.params_flat = flat
        self.unravel = unravel
        self.n_params = int(flat.shape[0])

    # ---- forward/loss helpers -------------------------------------------

    def _loss_single(self, params, xi, yi):
        logits, _ = self._single(
            self.cfg, params["lin"], params["oth"], xi, None, False, self.dtype
        )
        return ce_loss(logits, yi)

    def _logits_batch(self, params, x):
        fn = lambda xi: self._single(
            self.cfg, params["lin"], params["oth"], xi, None, False, self.dtype
        )[0]
        return jax.vmap(fn)(x)

    # ---- step variants ----------------------------------------------------

    def make_accum(self, variant: str, clip: float) -> Callable:
        """Build accum(params, acc, x, y, mask) for one clipping variant."""
        if variant == "nonprivate":
            return self._accum_nonprivate
        if variant in ("naive", "masked"):
            return functools.partial(self._accum_perexample, clip=clip)
        if variant in ("ghost", "bk"):
            if self.family not in GHOST_CAPABLE:
                raise ValueError(
                    f"{variant} clipping unsupported for {self.family} "
                    "(weight-standardized convs; matches the paper)"
                )
            return functools.partial(
                self._accum_ghost, clip=clip, bookkeeping=(variant == "bk")
            )
        raise KeyError(variant)

    def _accum_nonprivate(self, params_flat, acc, x, y, mask):
        """Batched-gradient SGD accumulate (the non-private baseline)."""

        def weighted_loss(pf):
            params = self.unravel(pf)
            lv = jax.vmap(lambda xi, yi: self._loss_single(params, xi, yi))(x, y)
            return jnp.sum(lv * mask), lv

        (loss_sum, lv), g = jax.value_and_grad(weighted_loss, has_aux=True)(
            params_flat
        )
        return acc + g, loss_sum, jnp.zeros_like(mask)

    def _accum_perexample(self, params_flat, acc, x, y, mask, *, clip):
        """Per-example grads -> Pallas fused clip-mask-accumulate (Alg. 2).

        The `naive` and `masked` variants share this graph; they differ
        operationally (naive is lowered per batch size, masked once)."""
        params = self.unravel(params_flat)
        bsz = x.shape[0]

        def one(xi, yi):
            return jax.value_and_grad(
                lambda p: self._loss_single(p, xi, yi)
            )(params)

        lv, gtree = jax.vmap(one)(x, y)
        g = _flatten_batch(gtree, bsz)  # [B, P]
        acc_out, sq = k_clip_accum(acc, g, mask, clip)
        return acc_out, jnp.sum(lv * mask), sq

    def _accum_ghost(self, params_flat, acc, x, y, mask, *, clip, bookkeeping):
        """Ghost clipping / Book Keeping for ViT linear layers.

        Pass A (one backward): vjp w.r.t. per-layer output perturbations
        gives every layer's per-example output-grads b_l; the `oth`
        subset (LayerNorm/cls/pos — ghost-unsupported layers) is tiled
        per example so the same vjp yields its per-example grads.
        Norms come from the Pallas ghost-norm kernel; then either
          ghost: second backward of the c_i-reweighted loss, or
          bk:    clipped sums rebuilt via einsum from (a_l, b_l, c_i).
        """
        params = self.unravel(params_flat)
        lin, oth = params["lin"], params["oth"]
        bsz = x.shape[0]
        pert0 = jax.tree.map(
            lambda p: jnp.broadcast_to(p, (bsz,) + p.shape),
            vit.zero_perturbs(self.cfg),
        )
        oth_t = jax.tree.map(lambda p: jnp.broadcast_to(p, (bsz,) + p.shape), oth)

        def f(pert_t, oth_tiled):
            def one(pt, ot, xi, yi):
                logits, acts = self._single(
                    self.cfg, lin, ot, xi, pt, True, self.dtype
                )
                return ce_loss(logits, yi), acts

            lv, acts = jax.vmap(one)(pert_t, oth_tiled, x, y)
            return jnp.sum(lv), (acts, lv)

        _, vjp_fn, (acts, lv) = jax.vjp(f, pert0, oth_t, has_aux=True)
        b_pert, g_oth = vjp_fn(jnp.ones(()))

        # Per-example squared norms: ghost trick for linear weights,
        # column-sum for biases, direct for the tiled `oth` grads.
        sq = jnp.zeros((bsz,), jnp.float32)
        for lname in self.cfg.linear_shapes():
            a, b = acts[lname], b_pert[lname]
            if a.ndim == 2:  # head: [B, d] -> [B, 1, d]
                a, b = a[:, None, :], b[:, None, :]
            sq = sq + k_ghost_sq_norm(a, b) + kref.bias_sq_norm(b)
        for leaf in jax.tree_util.tree_leaves(g_oth):
            sq = sq + jnp.sum(jnp.square(leaf.reshape(bsz, -1)), axis=1)

        c = jax.lax.stop_gradient(kref.clip_factors(sq, mask, clip))

        if bookkeeping:
            # One-pass: rebuild clipped grad sums from cached (a, b, c).
            glin = {}
            for lname in self.cfg.linear_shapes():
                a, b = acts[lname], b_pert[lname]
                if a.ndim == 2:
                    a, b = a[:, None, :], b[:, None, :]
                glin[lname] = {
                    "w": jnp.einsum("bti,bto,b->io", a, b, c),
                    "b": jnp.einsum("bto,b->o", b, c),
                }
            goth = jax.tree.map(
                lambda g: jnp.einsum("b...,b->...", g, c), g_oth
            )
            gflat, _ = ravel_pytree({"lin": glin, "oth": goth})
        else:
            # Ghost: second backward pass with the reweighted loss.
            def reweighted(pf):
                p = self.unravel(pf)
                lvv = jax.vmap(lambda xi, yi: self._loss_single(p, xi, yi))(x, y)
                return jnp.sum(lvv * c)

            gflat = jax.grad(reweighted)(params_flat)

        return acc + gflat, jnp.sum(lv * mask), sq

    # ---- apply & eval -------------------------------------------------------

    def apply_fn(self, params_flat, acc, seed, denom, lr, noise_mult):
        """Noise + SGD step (Pallas fused); one executable per model."""
        key = jax.random.PRNGKey(seed[0])
        noise = jax.random.normal(key, params_flat.shape, jnp.float32)
        return k_noisy_step(
            params_flat, acc, noise, denom[0], lr[0], noise_mult[0]
        )

    def eval_fn(self, params_flat, x, y):
        """(loss_sum, ncorrect) over an eval batch."""
        params = self.unravel(params_flat)
        logits = self._logits_batch(params, x)
        lv = jax.vmap(ce_loss)(logits, y)
        ncorrect = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return jnp.sum(lv), ncorrect
