"""Build-time-only Python package: JAX model (L2) + Pallas kernels (L1).

Nothing in here is imported at runtime; `make artifacts` lowers everything
to HLO text under artifacts/ and the Rust coordinator takes over.
"""
