"""AOT entry point: lower every (model x variant x batch) step graph to
HLO **text** under artifacts/, plus initial parameters and a manifest.

Run via `make artifacts`:   cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the Rust `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Python runs ONCE, here.  The Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import ModelBundle

# Default artifact set (kept modest: each executable is PJRT-compiled by
# the Rust side on a single CPU core).  Benches may request more via CLI.
DEFAULT_PLAN = {
    "vit-micro": {
        "variants": ["nonprivate", "naive", "masked", "ghost", "bk"],
        "batches": [2, 4, 8, 16, 32],
        "bf16": {"variants": ["nonprivate", "masked"], "batches": [8, 16]},
        "eval_batch": 32,
    },
    "vit-tiny": {
        "variants": ["nonprivate", "masked", "ghost", "bk"],
        "batches": [4, 8, 16],
        "bf16": {"variants": ["nonprivate", "masked"], "batches": [8]},
        "eval_batch": 16,
    },
    "rn-micro": {
        "variants": ["nonprivate", "naive", "masked"],
        "batches": [4, 8, 16],
        "bf16": None,
        "eval_batch": 16,
    },
}

CLIP_NORM = 1.0  # baked into the accum graphs; matches rust config default


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe route)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_model(name: str, plan: dict, out_dir: Path, seed: int) -> dict:
    """Lower one ladder rung per its plan; returns its manifest entry."""
    t0 = time.time()
    entry_execs = []

    def emit(fname: str, lowered, **meta):
        text = to_hlo_text(lowered)
        (out_dir / fname).write_text(text)
        entry_execs.append({"path": fname, **meta})
        print(f"  wrote {fname} ({len(text)/1e3:.0f} kB)")

    variants = plan["variants"]
    dtypes = [("f32", jnp.float32)]
    cfg0 = None
    for dtype_name, dtype in dtypes + (
        [("bf16", jnp.bfloat16)] if plan.get("bf16") else []
    ):
        mb = ModelBundle(name, seed=seed, dtype=dtype)
        cfg0 = mb.cfg
        p = mb.n_params
        img = (mb.cfg.image, mb.cfg.image, mb.cfg.channels)

        if dtype_name == "f32":
            # Initial params + apply + eval are emitted once (f32 master).
            np.asarray(mb.params_flat, dtype=np.float32).tofile(
                out_dir / f"{name}_init.bin"
            )
            lowered = jax.jit(mb.apply_fn).lower(
                spec((p,)), spec((p,)),
                spec((1,), jnp.int32), spec((1,)), spec((1,)), spec((1,)),
            )
            emit(f"{name}_apply.hlo.txt", lowered, kind="apply")
            eb = plan["eval_batch"]
            lowered = jax.jit(mb.eval_fn).lower(
                spec((p,)), spec((eb,) + img), spec((eb,), jnp.int32)
            )
            emit(f"{name}_eval_B{eb}.hlo.txt", lowered, kind="eval", batch=eb)
            todo_variants, todo_batches = variants, plan["batches"]
        else:
            todo_variants = plan["bf16"]["variants"]
            todo_batches = plan["bf16"]["batches"]

        for variant in todo_variants:
            accum = mb.make_accum(variant, CLIP_NORM)
            for b in todo_batches:
                lowered = jax.jit(accum).lower(
                    spec((p,)), spec((p,)),
                    spec((b,) + img), spec((b,), jnp.int32), spec((b,)),
                )
                sfx = "" if dtype_name == "f32" else f"_{dtype_name}"
                emit(
                    f"{name}_{variant}_B{b}{sfx}_accum.hlo.txt",
                    lowered,
                    kind="accum",
                    variant=variant,
                    batch=b,
                    dtype=dtype_name,
                )

    mb = ModelBundle(name, seed=seed)
    entry = {
        "family": mb.family,
        "n_params": mb.n_params,
        "image": cfg0.image,
        "channels": cfg0.channels,
        "num_classes": cfg0.num_classes,
        "clip_norm": CLIP_NORM,
        "flops_fwd_per_example": cfg0.flops_per_example(),
        "init_params": f"{name}_init.bin",
        "executables": entry_execs,
    }
    print(f"  {name}: {len(entry_execs)} executables in {time.time()-t0:.1f}s")
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=list(DEFAULT_PLAN))
    ap.add_argument("--batches", nargs="*", type=int, default=None,
                    help="override batch list for every model/variant")
    ap.add_argument("--variants", nargs="*", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = {"version": 1, "seed": args.seed, "models": {}}
    for name in args.models:
        plan = dict(DEFAULT_PLAN.get(
            name,
            {"variants": ["nonprivate", "masked"], "batches": [8],
             "bf16": None, "eval_batch": 8},
        ))
        if args.batches:
            plan["batches"] = args.batches
        if args.variants:
            plan["variants"] = args.variants
        print(f"lowering {name}: {plan}")
        manifest["models"][name] = lower_model(name, plan, out_dir, args.seed)
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"manifest.json written: {len(manifest['models'])} models")


if __name__ == "__main__":
    main()
