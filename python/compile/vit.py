"""Layer-2: Vision Transformer (ViT) in pure JAX, written for DP-SGD.

Design decisions driven by the paper:

* The forward pass is written **per example** (`vit_single`) and batched
  with `jax.vmap`.  Per-example structure is what DP-SGD fundamentally
  needs (per-example gradients / norms); XLA re-batches the matmuls, so
  the non-private baseline loses nothing.

* Parameters are split into two sub-trees:
    - `lin`: weight/bias of every linear layer — these support **ghost
      clipping** (norms from activations x output-grads, no per-example
      gradient materialization);
    - `oth`: LayerNorm scales/biases, cls token, position embeddings —
      the "unsupported layer" set that real ghost implementations
      (PrivateVision, FastDP) fall back to per-example gradients for.

* Every linear layer optionally adds a zero **perturbation** input with
  the layer's output shape.  The vector-Jacobian product with respect to
  that perturbation is exactly the layer's per-example output gradient
  b_i — the quantity ghost clipping and Book Keeping reuse (Bu et al.
  2023).  This is the JAX analogue of Opacus' backward hooks.

Model dims follow the paper's ViT ladder (Table 1) scaled to CPU-feasible
sizes; the paper-scale dims live in rust/src/models.rs for the analytic
memory/FLOP studies (Figures 3, 5; Table 3).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    """Architecture hyperparameters for one ladder rung."""

    name: str
    depth: int
    dim: int
    heads: int
    mlp_ratio: int = 4
    patch: int = 4
    image: int = 32
    channels: int = 3
    num_classes: int = 100

    @property
    def tokens(self) -> int:
        """Sequence length including the cls token."""
        return (self.image // self.patch) ** 2 + 1

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * self.channels

    def linear_shapes(self) -> dict[str, tuple[int, int]]:
        """(d_in, d_out) of every linear layer, keyed by layer name."""
        d, m = self.dim, self.mlp_ratio * self.dim
        shapes = {"embed": (self.patch_dim, d)}
        for i in range(self.depth):
            shapes[f"b{i}.qkv"] = (d, 3 * d)
            shapes[f"b{i}.proj"] = (d, d)
            shapes[f"b{i}.fc1"] = (d, m)
            shapes[f"b{i}.fc2"] = (m, d)
        shapes["head"] = (d, self.num_classes)
        return shapes

    def flops_per_example(self) -> float:
        """Forward FLOPs per example (2*MACs), matmuls + attention only."""
        t = self.tokens
        fl = 0.0
        for name, (d_in, d_out) in self.linear_shapes().items():
            seq = 1 if name == "head" else t  # head acts on the cls token only
            fl += 2.0 * seq * d_in * d_out
        # attention: QK^T and AV, per head
        fl += self.depth * 2 * (2.0 * t * t * self.dim)
        return fl


# The paper's ViT ladder (Table 1), scaled for a 1-core CPU testbed.
VIT_LADDER: dict[str, ViTConfig] = {
    "vit-micro": ViTConfig("vit-micro", depth=2, dim=64, heads=2, patch=8),
    "vit-tiny": ViTConfig("vit-tiny", depth=4, dim=128, heads=4, patch=4),
    "vit-small": ViTConfig("vit-small", depth=6, dim=192, heads=6, patch=4),
    "vit-base": ViTConfig("vit-base", depth=8, dim=256, heads=8, patch=4),
}


def _trunc_normal(key, shape, std=0.02):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)


def init_vit(key: jax.Array, cfg: ViTConfig) -> dict[str, Any]:
    """Initialize {lin: {...}, oth: {...}} parameter tree."""
    lin: dict[str, dict[str, jnp.ndarray]] = {}
    shapes = cfg.linear_shapes()
    keys = jax.random.split(key, len(shapes) + 2)
    for k, (name, (d_in, d_out)) in zip(keys[:-2], sorted(shapes.items())):
        lin[name] = {
            "w": _trunc_normal(k, (d_in, d_out), std=1.0 / math.sqrt(d_in)),
            "b": jnp.zeros((d_out,), jnp.float32),
        }
    oth: dict[str, jnp.ndarray] = {
        "cls": _trunc_normal(keys[-2], (cfg.dim,)),
        "pos": _trunc_normal(keys[-1], (cfg.tokens, cfg.dim)),
    }
    for i in range(cfg.depth):
        for ln in (f"b{i}.ln1", f"b{i}.ln2"):
            oth[f"{ln}.g"] = jnp.ones((cfg.dim,), jnp.float32)
            oth[f"{ln}.b"] = jnp.zeros((cfg.dim,), jnp.float32)
    oth["lnf.g"] = jnp.ones((cfg.dim,), jnp.float32)
    oth["lnf.b"] = jnp.zeros((cfg.dim,), jnp.float32)
    return {"lin": lin, "oth": oth}


def zero_perturbs(cfg: ViTConfig) -> dict[str, jnp.ndarray]:
    """Zero perturbation tree (single-example shapes: [T, d_out] / [nc])."""
    t = cfg.tokens
    pert = {}
    for name, (_, d_out) in cfg.linear_shapes().items():
        if name == "head":
            pert[name] = jnp.zeros((d_out,), jnp.float32)
        elif name == "embed":
            pert[name] = jnp.zeros((t - 1, d_out), jnp.float32)
        else:
            pert[name] = jnp.zeros((t, d_out), jnp.float32)
    return pert


def _dense(lin, name, a, perturbs, acts, dtype):
    """y = a @ W + b (+ perturbation); optionally record the input."""
    w = lin[name]["w"].astype(dtype)
    y = a.astype(dtype) @ w + lin[name]["b"].astype(dtype)
    if perturbs is not None:
        y = y + perturbs[name].astype(dtype)
    if acts is not None:
        acts[name] = a
    return y


def _layernorm(oth, name, x):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + 1e-6)
    return xhat * oth[f"{name}.g"] + oth[f"{name}.b"]


def patchify(cfg: ViTConfig, img: jnp.ndarray) -> jnp.ndarray:
    """[H, W, C] -> [T-1, patch*patch*C] raster-ordered patches."""
    p, n = cfg.patch, cfg.image // cfg.patch
    x = img.reshape(n, p, n, p, cfg.channels)
    x = x.transpose(0, 2, 1, 3, 4)
    return x.reshape(n * n, cfg.patch_dim)


def vit_single(
    cfg: ViTConfig,
    lin: dict,
    oth: dict,
    img: jnp.ndarray,
    perturbs: dict | None = None,
    collect: bool = False,
    dtype: jnp.dtype = jnp.float32,
):
    """Forward one example: [H, W, C] -> logits [num_classes].

    Returns (logits, acts) where acts maps linear-layer name -> its input
    activation (ghost clipping's `a_i`); acts is {} unless collect=True.
    """
    acts: dict[str, jnp.ndarray] | None = {} if collect else None
    t, d, h = cfg.tokens, cfg.dim, cfg.heads
    dh = d // h

    x = patchify(cfg, img)
    x = _dense(lin, "embed", x, perturbs, acts, dtype)  # [T-1, D]
    x = jnp.concatenate([oth["cls"][None].astype(dtype), x], axis=0)
    x = x + oth["pos"].astype(dtype)

    for i in range(cfg.depth):
        y = _layernorm(oth, f"b{i}.ln1", x)
        qkv = _dense(lin, f"b{i}.qkv", y, perturbs, acts, dtype)  # [T, 3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(t, h, dh).transpose(1, 0, 2)
        k = k.reshape(t, h, dh).transpose(1, 0, 2)
        v = v.reshape(t, h, dh).transpose(1, 0, 2)
        att = jnp.einsum("htd,hsd->hts", q, k) / math.sqrt(dh)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("hts,hsd->htd", att, v).transpose(1, 0, 2).reshape(t, d)
        x = x + _dense(lin, f"b{i}.proj", o, perturbs, acts, dtype)

        y = _layernorm(oth, f"b{i}.ln2", x)
        y = _dense(lin, f"b{i}.fc1", y, perturbs, acts, dtype)
        y = jax.nn.gelu(y)
        x = x + _dense(lin, f"b{i}.fc2", y, perturbs, acts, dtype)

    x = _layernorm(oth, "lnf", x)
    logits = _dense(lin, "head", x[0], perturbs, acts, dtype)
    return logits.astype(jnp.float32), (acts if collect else {})
