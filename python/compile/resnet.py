"""Layer-2: BiT-style ResNet (Kolesnikov et al. 2020) in pure JAX.

Big-Transfer ResNets replace BatchNorm with **GroupNorm** and use
**weight-standardized convolutions** — the exact combination the paper
notes is *incompatible* with PrivateVision's and FastDP's ghost clipping
("The non-Opacus implementations do not support the BiT ResNet due to
their custom weight standardization layer").  We reproduce that boundary:
the ResNet supports the nonprivate / naive per-example / masked (Alg. 2)
variants, while ghost/BK variants are ViT-only, as in the paper's Section
5.1.  The mix-ghost per-layer decision rule is still *modeled* for ResNets
at paper scale in rust/src/clipping.rs (it needs only layer dims).

Like vit.py, the forward is written per example and vmapped; convs on a
[1, H, W, C] tensor batch cleanly under vmap.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    """BiT-ResNet ladder rung: `depths` bottleneck blocks per stage,
    `width` base channels (BiT width-factor scales this)."""

    name: str
    depths: tuple[int, ...]
    width: int
    image: int = 32
    channels: int = 3
    num_classes: int = 100
    groups: int = 8

    def stage_channels(self) -> list[int]:
        # Bottleneck expansion 4, channel doubling per stage (BiT layout).
        return [self.width * (2**i) * 4 for i in range(len(self.depths))]

    def flops_per_example(self) -> float:
        """Rough forward FLOPs (convs only), for manifest/roofline use."""
        h = self.image
        fl = 2.0 * h * h * 9 * self.channels * self.width
        cin = self.width
        for i, (d, cout) in enumerate(zip(self.depths, self.stage_channels())):
            if i > 0:
                h //= 2
            mid = cout // 4
            for _ in range(d):
                fl += 2.0 * h * h * (cin * mid + 9 * mid * mid + mid * cout)
                cin = cout
        fl += 2.0 * cin * self.num_classes
        return fl


# CPU-scaled ladder mirroring the paper's BiT R50x1 -> R152x4 progression
# (depth grows down the ladder, width grows via the xN factor).
RESNET_LADDER: dict[str, ResNetConfig] = {
    "rn-micro": ResNetConfig("rn-micro", depths=(1, 1), width=8),
    "rn-small": ResNetConfig("rn-small", depths=(1, 1, 1), width=16),
    "rn-base": ResNetConfig("rn-base", depths=(2, 2, 2), width=16),
    "rn-wide": ResNetConfig("rn-wide", depths=(1, 1, 1), width=32),
}


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) / jnp.sqrt(fan_in)


def init_resnet(key: jax.Array, cfg: ResNetConfig) -> dict[str, Any]:
    """Parameter tree {lin: {head}, oth: {convs, groupnorms}}.

    Convs live in `oth` (per-example-grad territory — ghost clipping does
    not apply to weight-standardized convs, matching the paper); only the
    final dense head is in `lin`.
    """
    params_oth: dict[str, jnp.ndarray] = {}
    keys = iter(jax.random.split(key, 4096))

    def gn(name, c):
        params_oth[f"{name}.g"] = jnp.ones((c,), jnp.float32)
        params_oth[f"{name}.b"] = jnp.zeros((c,), jnp.float32)

    params_oth["root.w"] = _conv_init(next(keys), 3, 3, cfg.channels, cfg.width)
    cin = cfg.width
    for s, (d, cout) in enumerate(zip(cfg.depths, cfg.stage_channels())):
        mid = cout // 4
        for b in range(d):
            p = f"s{s}b{b}"
            gn(f"{p}.gn1", cin)
            params_oth[f"{p}.c1.w"] = _conv_init(next(keys), 1, 1, cin, mid)
            gn(f"{p}.gn2", mid)
            params_oth[f"{p}.c2.w"] = _conv_init(next(keys), 3, 3, mid, mid)
            gn(f"{p}.gn3", mid)
            params_oth[f"{p}.c3.w"] = _conv_init(next(keys), 1, 1, mid, cout)
            if b == 0:
                params_oth[f"{p}.proj.w"] = _conv_init(next(keys), 1, 1, cin, cout)
            cin = cout
    gn("gnf", cin)
    head_key = next(keys)
    lin = {
        "head": {
            "w": jax.random.normal(head_key, (cin, cfg.num_classes), jnp.float32)
            / jnp.sqrt(cin),
            "b": jnp.zeros((cfg.num_classes,), jnp.float32),
        }
    }
    return {"lin": lin, "oth": params_oth}


def _ws(w: jnp.ndarray) -> jnp.ndarray:
    """Weight standardization (BiT): zero-mean unit-var per output filter."""
    mu = jnp.mean(w, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(w, axis=(0, 1, 2), keepdims=True)
    return (w - mu) * jax.lax.rsqrt(var + 1e-10)


def _conv(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """NHWC conv, SAME padding, weight standardized."""
    return jax.lax.conv_general_dilated(
        x,
        _ws(w),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _groupnorm(oth, name, x, groups):
    c = x.shape[-1]
    g = min(groups, c)
    shp = x.shape[:-1] + (g, c // g)
    xg = x.reshape(shp)
    mu = jnp.mean(xg, axis=(0, 1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(0, 1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + 1e-5)
    return xg.reshape(x.shape) * oth[f"{name}.g"] + oth[f"{name}.b"]


def resnet_single(
    cfg: ResNetConfig,
    lin: dict,
    oth: dict,
    img: jnp.ndarray,
    perturbs: dict | None = None,
    collect: bool = False,
    dtype: jnp.dtype = jnp.float32,
):
    """Forward one example: [H, W, C] -> logits [num_classes].

    perturbs/collect support only the head linear (ghost clipping is not
    applicable to weight-standardized convs — see module docstring).
    """
    acts: dict[str, jnp.ndarray] | None = {} if collect else None
    x = img[None].astype(dtype)  # [1, H, W, C]
    x = _conv(x, oth["root.w"].astype(dtype))
    cin = cfg.width
    for s, (d, cout) in enumerate(zip(cfg.depths, cfg.stage_channels())):
        for b in range(d):
            p = f"s{s}b{b}"
            stride = 2 if (s > 0 and b == 0) else 1
            y = jax.nn.relu(_groupnorm(oth, f"{p}.gn1", x, cfg.groups))
            sc = x
            if b == 0:
                sc = _conv(y, oth[f"{p}.proj.w"].astype(dtype), stride)
            y = _conv(y, oth[f"{p}.c1.w"].astype(dtype))
            y = jax.nn.relu(_groupnorm(oth, f"{p}.gn2", y, cfg.groups))
            y = _conv(y, oth[f"{p}.c2.w"].astype(dtype), stride)
            y = jax.nn.relu(_groupnorm(oth, f"{p}.gn3", y, cfg.groups))
            y = _conv(y, oth[f"{p}.c3.w"].astype(dtype))
            x = sc + y
            cin = cout
    x = jax.nn.relu(_groupnorm(oth, "gnf", x, cfg.groups))
    pooled = jnp.mean(x, axis=(1, 2))[0]  # [C]
    w = lin["head"]["w"].astype(dtype)
    logits = pooled @ w + lin["head"]["b"].astype(dtype)
    if perturbs is not None:
        logits = logits + perturbs["head"].astype(dtype)
    if acts is not None:
        acts["head"] = pooled
    return logits.astype(jnp.float32), (acts if collect else {})
