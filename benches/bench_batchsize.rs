//! Bench: the max-physical-batch memory planner (paper Fig 3 / Table 3)
//! — model-ladder sweep at both GPU budgets, plus planner latency.
//!
//! `cargo bench --bench bench_batchsize`

use dp_shortcuts::clipping::ClippingMethod;
use dp_shortcuts::memory::{MemModel, A100_BYTES, V100_BYTES};
use dp_shortcuts::models::paper_ladder;
use dp_shortcuts::util::bench::bench;

fn main() {
    println!("== bench_batchsize (Fig 3 / Table 3) ==");
    let mem = MemModel::default();
    for (gpu, budget) in [("A100-40GB", A100_BYTES), ("V100-32GB", V100_BYTES)] {
        println!("-- {gpu} --");
        println!(
            "{:<12} {:>11} {:>11} {:>11} {:>11} {:>8}",
            "model", "nonprivate", "per-example", "ghost", "bk", "np/pe"
        );
        for arch in paper_ladder() {
            let np = mem.max_physical_batch(&arch, ClippingMethod::NonPrivate, budget);
            let pe = mem.max_physical_batch(&arch, ClippingMethod::PerExample, budget);
            let (gh, bk) = if ClippingMethod::Ghost.supports(arch.family) {
                (
                    mem.max_physical_batch(&arch, ClippingMethod::Ghost, budget),
                    mem.max_physical_batch(&arch, ClippingMethod::BkGhost, budget),
                )
            } else {
                (0, 0)
            };
            println!(
                "{:<12} {:>11} {:>11} {:>11} {:>11} {:>7.1}x",
                arch.name,
                np,
                pe,
                gh,
                bk,
                np as f64 / pe.max(1) as f64
            );
        }
    }
    // Planner latency (it sits on interactive paths in the launcher).
    let ladder = paper_ladder();
    let stats = bench("planner/full-ladder-sweep", 3, 20, || {
        let mem = MemModel::default();
        for arch in &ladder {
            for m in ClippingMethod::ALL {
                if m.supports(arch.family) {
                    std::hint::black_box(mem.max_physical_batch(arch, *m, A100_BYTES));
                }
            }
        }
    });
    println!("{stats}");
}
