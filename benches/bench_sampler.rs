//! Bench: Poisson subsampling + batch splitting cost — the L3 overhead
//! that proper (non-shortcut) sampling adds to every step. The paper's
//! efficiency argument only holds if this is negligible next to the
//! gradient computation; this bench proves it.
//!
//! `cargo bench --bench bench_sampler`

use dp_shortcuts::coordinator::batcher::{BatchMemoryManager, BatchingMode};
use dp_shortcuts::coordinator::sampler::{PoissonSampler, Sampler, ShuffleSampler};
use dp_shortcuts::util::bench::bench;

fn main() {
    println!("== bench_sampler ==");
    // The paper's full-scale setting: N = 50 000, q = 0.5 (E[L] = 25 000).
    for (n, q) in [(50_000u32, 0.5), (50_000, 0.01), (1_000_000, 0.001)] {
        let s = PoissonSampler::new(n, q, 0);
        let mut step = 0u64;
        let stats = bench(&format!("poisson/N{n}-q{q}"), 5, 100, || {
            std::hint::black_box(s.sample(step));
            step += 1;
        });
        println!("{stats}");
    }

    let s = ShuffleSampler::new(50_000, 25_000, 0);
    let mut step = 0u64;
    let stats = bench("shuffle-shortcut/N50k-B25k", 5, 100, || {
        std::hint::black_box(s.sample(step));
        step += 1;
    });
    println!("{stats}  (the 'shortcut' being avoided)");

    // Batch splitting (Algorithm 2 masking) over a 25k logical batch.
    let sampler = PoissonSampler::new(50_000, 0.5, 0);
    let logical = sampler.sample(0);
    let bmm = BatchMemoryManager::new(256, BatchingMode::Masked);
    let stats = bench("split/masked-25k-into-256", 5, 200, || {
        std::hint::black_box(bmm.split(&logical));
    });
    println!("{stats}");

    let stats = bench("split/naive-sizes-25k", 5, 200, || {
        std::hint::black_box(BatchMemoryManager::split_naive(
            &logical,
            &[32, 64, 128, 256],
        ));
    });
    println!("{stats}");
}
