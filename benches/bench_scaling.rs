//! Bench: the multi-GPU scaling simulation (paper Fig 7 / A.4 / A.5) —
//! full curves for private vs non-private plus simulator latency.
//!
//! `cargo bench --bench bench_scaling`

use dp_shortcuts::cluster::{amdahl_speedup, fit_parallel_fraction, ClusterSim, Interconnect};
use dp_shortcuts::util::bench::bench;

fn sim(thr: f64) -> ClusterSim {
    ClusterSim {
        single_worker_throughput: thr,
        local_batch: 32,
        grad_bytes: 86.6e6 * 4.0, // ViT-Base fp32 grads
        overlap: 0.5,
        serial_overhead: 1.0e-3,
        interconnect: Interconnect::default(),
    }
}

fn main() {
    println!("== bench_scaling (Fig 7 / A.4 / A.5) ==");
    let gpus = [1usize, 2, 4, 8, 16, 32, 64, 80];
    // Paper-testbed-like single-GPU rates for ViT-Base on V100:
    // non-private ~2.8x the private rate (Fig 2).
    for (label, thr) in [("non-private", 1400.0), ("private", 500.0)] {
        let curve = sim(thr).curve(&gpus);
        println!("-- {label} --");
        for p in &curve {
            println!(
                "  {:>3} GPUs: {:>9.0} ex/s ({:>5.1}% of ideal)",
                p.gpus,
                p.throughput,
                100.0 * p.efficiency
            );
        }
        let pts: Vec<(f64, f64)> = curve
            .iter()
            .filter(|p| p.gpus > 1)
            .map(|p| (p.gpus as f64, p.throughput / curve[0].throughput))
            .collect();
        let frac = fit_parallel_fraction(&pts);
        println!(
            "  Amdahl p = {:.3}% -> predicted speedup@80 = {:.1}x",
            100.0 * frac,
            amdahl_speedup(frac, 80.0)
        );
    }
    println!("(paper: private 69.2% vs non-private 53.3% of ideal at 80 GPUs;");
    println!(" Amdahl 99.5% vs 98.9%)");

    let s = bench("simulate/80-gpu-curve", 10, 200, || {
        std::hint::black_box(sim(500.0).curve(&gpus));
    });
    println!("{s}");
}
