//! Bench: RDP accountant + calibration cost, and the epsilon tables the
//! paper's hyperparameters imply (Table A2 settings).
//!
//! `cargo bench --bench bench_accountant`

use dp_shortcuts::privacy::{calibrate_sigma, RdpAccountant};
use dp_shortcuts::util::bench::bench;

fn main() {
    println!("== bench_accountant ==");
    let acc = RdpAccountant::default();

    // The paper's setting and a classic large-T setting.
    for (q, sigma, steps, delta) in [
        (0.5, 0.9238, 4u64, 2.04e-5),
        (0.01, 1.1, 10_000, 1e-5),
        (0.001, 0.6, 100_000, 1e-6),
    ] {
        let eps = acc.epsilon(q, sigma, steps, delta);
        println!("q={q:<6} sigma={sigma:<7} T={steps:<7} -> eps={eps:.4}");
    }

    let s = bench("epsilon/q0.5-T4", 10, 500, || {
        std::hint::black_box(RdpAccountant::default().epsilon(0.5, 0.9238, 4, 2.04e-5));
    });
    println!("{s}");

    let s = bench("epsilon/q0.01-T10k", 10, 200, || {
        std::hint::black_box(RdpAccountant::default().epsilon(0.01, 1.1, 10_000, 1e-5));
    });
    println!("{s}");

    let s = bench("calibrate/paper-setting", 3, 50, || {
        std::hint::black_box(calibrate_sigma(8.0, 2.04e-5, 0.5, 4).unwrap());
    });
    println!("{s}");

    // RDP vs PLD: the tighter Fourier accountant (ablation).
    println!("-- RDP vs PLD epsilon (same mechanism) --");
    for (q, sigma, steps, delta) in [(0.01, 1.1, 1000u32, 1e-5), (0.1, 1.0, 100, 1e-5)] {
        let e_rdp = acc.epsilon(q, sigma, steps as u64, delta);
        let e_pld = dp_shortcuts::privacy::pld_epsilon(q, sigma, steps, delta);
        println!(
            "q={q:<5} sigma={sigma:<4} T={steps:<5}: RDP {e_rdp:.4}  PLD {e_pld:.4}  (gap {:.1}%)",
            100.0 * (e_rdp - e_pld) / e_rdp
        );
    }
    let s = bench("pld/T100-4096buckets", 1, 5, || {
        std::hint::black_box(dp_shortcuts::privacy::pld_epsilon(0.1, 1.0, 100, 1e-5));
    });
    println!("{s}");

    // Per-step streaming accounting must be cheap enough for the hot
    // loop (it runs once per optimizer step in the trainer).
    let mut streaming =
        dp_shortcuts::privacy::rdp::StreamingAccountant::new(RdpAccountant::default());
    let s = bench("streaming/record_step", 10, 1000, || {
        streaming.record_step(0.5, 0.9238);
    });
    println!("{s}");
}
