//! Bench: lower-precision study (paper Fig 5 / A.3) — measured bf16
//! vs f32 executables plus the paper-scale TF32 roofline table.
//!
//! `cargo bench --bench bench_precision`

use dp_shortcuts::clipping::ClippingMethod;
use dp_shortcuts::coordinator::config::TrainConfig;
use dp_shortcuts::coordinator::trainer::Trainer;
use dp_shortcuts::metrics::summary_with_ci;
use dp_shortcuts::models::paper_ladder;
use dp_shortcuts::precision::Tf32Model;
use dp_shortcuts::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    println!("== bench_precision (Fig 5 / A.3) ==");

    println!("-- modeled TF32/FP32 throughput ratio at paper scale --");
    let tf = Tf32Model::default();
    println!("{:<12} {:>12} {:>12}", "model", "non-private", "private");
    for a in &paper_ladder()[..5] {
        println!(
            "{:<12} {:>12.3} {:>12.3}",
            a.name,
            tf.throughput_ratio(a, ClippingMethod::NonPrivate),
            tf.throughput_ratio(a, ClippingMethod::PerExample)
        );
    }

    // Artifacts + PJRT when available, pure-Rust reference otherwise
    // (the reference catalog has no bf16 variants, so the measured
    // section prints nothing there — the modeled table above still runs).
    let rt = Runtime::auto("artifacts")?;
    println!(
        "-- measured bf16/f32 ratio (the CPU TF32 substitute, backend {}) --",
        rt.backend_name()
    );
    let names: Vec<String> = rt.manifest().models.keys().cloned().collect();
    for model in &names {
        let meta = rt.manifest().model(model)?.clone();
        for variant in ["nonprivate", "masked"] {
            for &b in meta.accum_batches(variant, "bf16").iter() {
                if !meta.accum_batches(variant, "f32").contains(&b) {
                    continue;
                }
                let mut thr = [0.0f64; 2];
                for (i, bf16) in [false, true].into_iter().enumerate() {
                    let cfg = TrainConfig {
                        model: model.clone(),
                        variant: variant.into(),
                        bf16,
                        physical_batch: b,
                        ..Default::default()
                    };
                    let t = Trainer::new(&rt, cfg)?;
                    let samples = t.bench_accum(variant, b, 8)?;
                    thr[i] = summary_with_ci(&samples, 0).median;
                }
                println!(
                    "{model:<12} {variant:<12} B={b:<4} f32 {:>8.1} ex/s  bf16 {:>8.1} ex/s  ratio {:.3}",
                    thr[0],
                    thr[1],
                    thr[1] / thr[0]
                );
            }
        }
    }
    Ok(())
}
