//! Bench: throughput per clipping method x model x physical batch —
//! regenerates the data behind paper Figures 1, 2, 4 and 6.
//!
//! `cargo bench --bench bench_throughput`
//!
//! Thin wrapper over the shared sweep harness (`dp_shortcuts::benchreport`,
//! the same engine as `dpshort bench`): runs the full accum/apply sweep,
//! prints per-config medians with bootstrap CIs and the speed relative
//! to the non-private baseline, and writes `BENCH_throughput.json` so
//! the run is recorded machine-readably.

use dp_shortcuts::benchreport::{run_sweep, SweepOptions, DEFAULT_OUT};
use dp_shortcuts::runtime::Runtime;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    // Artifacts + PJRT when available, pure-Rust reference otherwise.
    let rt = Runtime::auto("artifacts")?;
    println!("== bench_throughput (Figs 1/2/4/6, backend {}) ==", rt.backend_name());
    let mut opts = SweepOptions::new(false);
    opts.repeats = 8;
    let report = run_sweep(&rt, &opts)?;
    for e in &report.entries {
        match e.kind.as_str() {
            "accum" => {
                let variant = e.variant.as_deref().unwrap_or("?");
                let batch = e.batch.unwrap_or(0);
                // Relative throughput vs the non-private baseline at the
                // same batch (the Fig. 1/2 normalization).
                let rel = report
                    .accum_entry(&e.model, "nonprivate", batch)
                    .map(|base| e.median / base.median)
                    .unwrap_or(f64::NAN);
                println!(
                    "{:<32} {:>10.1} ex/s [{:>9.1},{:>9.1}] n={:<3} rel={rel:.2}",
                    format!("{}/{}/B{}", e.model, variant, batch),
                    e.median,
                    e.ci_low,
                    e.ci_high,
                    e.n
                );
            }
            _ => println!(
                "{:<32} {:>10.1} calls/s [{:>9.1},{:>9.1}] n={}",
                format!("{}/apply", e.model),
                e.median,
                e.ci_low,
                e.ci_high,
                e.n
            ),
        }
    }
    report.write(Path::new(DEFAULT_OUT))?;
    println!("wrote {DEFAULT_OUT} ({} entries)", report.entries.len());
    Ok(())
}
