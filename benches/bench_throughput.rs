//! Bench: throughput per clipping method x model x physical batch —
//! regenerates the data behind paper Figures 1, 2, 4 and 6.
//!
//! `cargo bench --bench bench_throughput`

use dp_shortcuts::coordinator::config::TrainConfig;
use dp_shortcuts::coordinator::trainer::Trainer;
use dp_shortcuts::metrics::summary_with_ci;
use dp_shortcuts::runtime::Runtime;
use dp_shortcuts::util::bench::stats_from;

fn main() -> anyhow::Result<()> {
    // Artifacts + PJRT when available, pure-Rust reference otherwise.
    let rt = Runtime::auto("artifacts")?;
    println!("== bench_throughput (Figs 1/2/4/6, backend {}) ==", rt.backend_name());
    let names: Vec<String> = rt.manifest().models.keys().cloned().collect();
    for model in &names {
        let meta = rt.manifest().model(model)?.clone();
        // Baselines first: non-private throughput per batch size.
        let mut baseline: std::collections::BTreeMap<usize, f64> = Default::default();
        for b in meta.accum_batches("nonprivate", "f32") {
            let cfg = TrainConfig {
                model: model.clone(),
                variant: "nonprivate".into(),
                physical_batch: b,
                ..Default::default()
            };
            let t = Trainer::new(&rt, cfg)?;
            let samples = t.bench_accum("nonprivate", b, 8)?;
            baseline.insert(b, summary_with_ci(&samples, 0).median);
        }
        for variant in meta.variants() {
            if variant == "naive" {
                continue;
            }
            for b in meta.accum_batches(&variant, "f32") {
                let cfg = TrainConfig {
                    model: model.clone(),
                    variant: variant.clone(),
                    physical_batch: b,
                    ..Default::default()
                };
                let t = Trainer::new(&rt, cfg)?;
                let samples = t.bench_accum(&variant, b, 8)?;
                let per_iter: Vec<f64> = samples.iter().map(|s| b as f64 / s).collect();
                let stats = stats_from(&format!("{model}/{variant}/B{b}"), &per_iter);
                let ci = summary_with_ci(&samples, 0);
                let rel = baseline.get(&b).map(|base| ci.median / base).unwrap_or(f64::NAN);
                println!(
                    "{stats}  -> {:>9.1} ex/s [{:>8.1},{:>8.1}] rel={rel:.2}",
                    ci.median, ci.ci_low, ci.ci_high
                );
            }
        }
    }
    Ok(())
}
